#!/usr/bin/env python
"""Docs gate: intra-repo links, CLI snippets and named gate keys must
match the tree.

Three checks over README.md, ROADMAP.md and docs/*.md (the curated docs —
not the paper/issue scratch files):

1. **Links** — every relative markdown link `[text](path)` must resolve
   to a file or directory in the repo (fragments are stripped; http(s)/
   mailto/pure-anchor links are skipped).  Docs rot silently when a file
   moves; this makes the rot a CI failure.

2. **CLI snippets** — inside fenced code blocks, any command line that
   invokes the serving CLI (`repro.launch.serve` / `launch/serve.py`) or
   the bench driver (`benchmarks/run.py`) may only use flags the tool
   actually accepts: serve flags are parsed from `--help` (so the check
   tracks argparse, not a hand-kept list), run.py flags from its source
   literals (it parses argv by hand).  A renamed flag breaks the doc's
   copy-paste path; this catches it at PR time.

3. **Gate keys** — every backticked identifier shaped like a perf-gate
   metric (contains ``_vs_``, ends in ``_improvement``, or is one of the
   gate-owned leaves ``tok_s`` / ``dispatches_per_token`` /
   ``token_match_rate``) must exist as a leaf in
   benchmarks/baseline.json.  docs/perf.md documents the gates by key
   name; a key renamed in the bench but not the docs (or documented
   before its baseline section landed) would otherwise point readers at
   a metric the gate no longer owns.

Exit 0 clean, 1 with one line per problem.  Run from anywhere:
    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from typing import List, Set

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
FLAG_RE = re.compile(r"(?<![\w-])--[a-zA-Z][\w-]*")
# a command line "uses" a tool when it names its module or script path
SERVE_RE = re.compile(r"(repro\.launch\.serve|launch/serve\.py)")
RUNPY_RE = re.compile(r"benchmarks/run\.py")
# backticked identifiers that look like perf-gate metric names
TICKED_RE = re.compile(r"`([a-z][a-z0-9_]*)`")
_GATE_LEAVES = ("tok_s", "dispatches_per_token", "token_match_rate")


def _gate_key_shaped(name: str) -> bool:
    return ("_vs_" in name or name.endswith("_improvement")
            or name in _GATE_LEAVES)


def doc_files() -> List[str]:
    files = [os.path.join(ROOT, n) for n in ("README.md", "ROADMAP.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, n) for n in os.listdir(docs)
                        if n.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def check_links(path: str, text: str, errors: List[str]) -> None:
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#", 1)[0]
        if not target:  # pure in-page anchor
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                          f"-> {m.group(1)}")


def serve_flags() -> Set[str]:
    """The serving CLI's accepted flags, from argparse itself."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    if out.returncode != 0:
        raise SystemExit("check_docs: `repro.launch.serve --help` failed:\n"
                         + out.stderr)
    return set(FLAG_RE.findall(out.stdout))


def runpy_flags() -> Set[str]:
    """benchmarks/run.py parses argv by hand — its accepted flags are the
    `--...` string literals in the source (collected from the AST, so an
    apostrophe inside some unrelated string can't desync the scan the way
    a quote-pairing regex would)."""
    import ast
    with open(os.path.join(ROOT, "benchmarks", "run.py")) as f:
        tree = ast.parse(f.read())
    flags: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            flags |= set(FLAG_RE.findall(node.value))
    return flags


def baseline_gate_keys() -> Set[str]:
    """Leaf key names of the committed perf baseline (the names
    benchmarks/run.py's gate walks), minus provenance stamps."""
    import json
    with open(os.path.join(ROOT, "benchmarks", "baseline.json")) as f:
        base = json.load(f)
    for k in ("_meta", "_run_meta", "rows"):
        base.pop(k, None)
    keys: Set[str] = set()

    def walk(tree) -> None:
        if isinstance(tree, dict):
            for k, v in tree.items():
                if isinstance(v, dict):
                    walk(v)
                else:
                    keys.add(k)

    walk(base)
    return keys


def check_gate_keys(path: str, text: str, known: Set[str],
                    errors: List[str]) -> None:
    rel = os.path.relpath(path, ROOT)
    for name in sorted({m.group(1) for m in TICKED_RE.finditer(text)}):
        if _gate_key_shaped(name) and name not in known:
            errors.append(
                f"{rel}: gate key `{name}` has no leaf in "
                f"benchmarks/baseline.json — renamed in the bench, or "
                f"documented before its baseline section was committed")


def check_cli_snippets(path: str, text: str, serve: Set[str],
                       runpy: Set[str], errors: List[str]) -> None:
    rel = os.path.relpath(path, ROOT)
    for block in FENCE_RE.findall(text):
        # join shell line continuations so a wrapped command is one line
        for line in block.replace("\\\n", " ").splitlines():
            for tool_re, known, name in ((SERVE_RE, serve, "serve.py"),
                                         (RUNPY_RE, runpy,
                                          "benchmarks/run.py")):
                m = tool_re.search(line)
                if not m:
                    continue
                used = set(FLAG_RE.findall(line[m.end():]))
                for flag in sorted(used - known):
                    errors.append(f"{rel}: snippet flag {flag} not "
                                  f"accepted by {name}: {line.strip()}")


def main() -> int:
    errors: List[str] = []
    serve, runpy = serve_flags(), runpy_flags()
    gate_keys = baseline_gate_keys()
    files = doc_files()
    for path in files:
        with open(path) as f:
            text = f.read()
        check_links(path, text, errors)
        check_cli_snippets(path, text, serve, runpy, errors)
        check_gate_keys(path, text, gate_keys, errors)
    if errors:
        print(f"check_docs: {len(errors)} problem(s) in {len(files)} files")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK ({len(files)} files, "
          f"{len(serve)} serve flags, {len(runpy)} run.py flags, "
          f"{len(gate_keys)} baseline gate keys)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
