#!/usr/bin/env python
"""Docs gate: intra-repo links and CLI snippets must match the tree.

Two checks over README.md, ROADMAP.md and docs/*.md (the curated docs —
not the paper/issue scratch files):

1. **Links** — every relative markdown link `[text](path)` must resolve
   to a file or directory in the repo (fragments are stripped; http(s)/
   mailto/pure-anchor links are skipped).  Docs rot silently when a file
   moves; this makes the rot a CI failure.

2. **CLI snippets** — inside fenced code blocks, any command line that
   invokes the serving CLI (`repro.launch.serve` / `launch/serve.py`) or
   the bench driver (`benchmarks/run.py`) may only use flags the tool
   actually accepts: serve flags are parsed from `--help` (so the check
   tracks argparse, not a hand-kept list), run.py flags from its source
   literals (it parses argv by hand).  A renamed flag breaks the doc's
   copy-paste path; this catches it at PR time.

Exit 0 clean, 1 with one line per problem.  Run from anywhere:
    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from typing import List, Set

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
FLAG_RE = re.compile(r"(?<![\w-])--[a-zA-Z][\w-]*")
# a command line "uses" a tool when it names its module or script path
SERVE_RE = re.compile(r"(repro\.launch\.serve|launch/serve\.py)")
RUNPY_RE = re.compile(r"benchmarks/run\.py")


def doc_files() -> List[str]:
    files = [os.path.join(ROOT, n) for n in ("README.md", "ROADMAP.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, n) for n in os.listdir(docs)
                        if n.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def check_links(path: str, text: str, errors: List[str]) -> None:
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#", 1)[0]
        if not target:  # pure in-page anchor
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                          f"-> {m.group(1)}")


def serve_flags() -> Set[str]:
    """The serving CLI's accepted flags, from argparse itself."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    if out.returncode != 0:
        raise SystemExit("check_docs: `repro.launch.serve --help` failed:\n"
                         + out.stderr)
    return set(FLAG_RE.findall(out.stdout))


def runpy_flags() -> Set[str]:
    """benchmarks/run.py parses argv by hand — its accepted flags are the
    `--...` string literals in the source."""
    with open(os.path.join(ROOT, "benchmarks", "run.py")) as f:
        src = f.read()
    return set(FLAG_RE.findall(" ".join(re.findall(r"[\"']([^\"']*)[\"']",
                                                   src))))


def check_cli_snippets(path: str, text: str, serve: Set[str],
                       runpy: Set[str], errors: List[str]) -> None:
    rel = os.path.relpath(path, ROOT)
    for block in FENCE_RE.findall(text):
        # join shell line continuations so a wrapped command is one line
        for line in block.replace("\\\n", " ").splitlines():
            for tool_re, known, name in ((SERVE_RE, serve, "serve.py"),
                                         (RUNPY_RE, runpy,
                                          "benchmarks/run.py")):
                m = tool_re.search(line)
                if not m:
                    continue
                used = set(FLAG_RE.findall(line[m.end():]))
                for flag in sorted(used - known):
                    errors.append(f"{rel}: snippet flag {flag} not "
                                  f"accepted by {name}: {line.strip()}")


def main() -> int:
    errors: List[str] = []
    serve, runpy = serve_flags(), runpy_flags()
    files = doc_files()
    for path in files:
        with open(path) as f:
            text = f.read()
        check_links(path, text, errors)
        check_cli_snippets(path, text, serve, runpy, errors)
    if errors:
        print(f"check_docs: {len(errors)} problem(s) in {len(files)} files")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK ({len(files)} files, "
          f"{len(serve)} serve flags, {len(runpy)} run.py flags)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
