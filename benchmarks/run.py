"""Benchmark harness — one function per paper table/figure (§8, §9).

Prints ``name,us_per_call,derived`` CSV rows.  All measurements are CPU
wall-clock of the jnp integer path (the kernels' oracle math); the TPU
projection (table5/section9 analogues) comes from the roofline module, which
is exactly the paper's §9 methodology (measure proof-of-concept, project
analytically onto the target part).

  table1: per-encoder latency components T (and fitted X) vs sequence length
  table2: Eq.1 full-model (12-encoder pipeline) latency estimates
  table3: padded vs no-padding latency (GLUE avg len 38, paper's headline)
  table4: throughput, padded vs packed (inferences/s)
  table5: comparison row vs the paper's published accelerator numbers
  fig15 : "resource utilization" analogue — Cluster-Builder kernel counts
          and routing-table entries (2N-1 vs N^2)
  sec9  : v5e int8 roofline estimate of encoder latency (Versal analogue)
  gmi   : collective byte models — composed vs fused vs gateway-hierarchical
  serve_cb: wave vs continuous-batching serving throughput + TTFT (§8.2)
  serve_paged: paged KV + radix prefix reuse vs dense slots at equal KV HBM
          (also via ``serve_cb --shared-prefix``)
  serve_quant: int8 KV-cache pages vs bf16 paged at equal KV HBM + greedy
          token-match rate (also via ``serve_cb --kv-dtype int8``)
  serve_spec: greedy speculative decoding (draft lookahead + one batched
          verify) vs the plain fused-scan engine on a decode-bound stream,
          with lossless token-match gating (also via ``serve --draft-config``)
  serve_throughput: exact=False serve_pipeline (request-skewed schedule +
          stage-local KV arenas) vs the exact drained pipeline on the
          forced multi-device host mesh, token streams gated by the 0.98
          match band (also via ``serve --plan serve_pipeline --no-exact``)

Run everything with no args, or a subset: ``python benchmarks/run.py serve_cb``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

SEQ_LENS = (1, 2, 4, 8, 16, 32, 64, 128)
ROWS: List[str] = []


def row(name: str, us: float, derived: str = "") -> None:
    line = f"{name},{us:.1f},{derived}"
    ROWS.append(line)
    print(line)


def _median_time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _one_layer_setup():
    from repro.configs import get_config
    from repro.models import ibert as ib

    cfg = get_config("ibert-base")
    cfg1 = dataclasses.replace(cfg, n_layers=1, max_seq_len=128)
    key = jax.random.PRNGKey(0)
    params = ib.init_ibert_params(cfg1, key)
    toks = jax.random.randint(key, (1, 128), 0, cfg1.vocab_size)
    act = ib.calibrate(params, cfg1, toks)
    qp = ib.quantize_ibert(params, cfg1, act)
    return cfg1, qp


def table1_encoder_latency(state: Dict) -> None:
    """Paper Table 1 analogue: one-encoder latency T per sequence length;
    X fitted at the paper's ratio (X ~= 0.53 T at seq 128, §9)."""
    from repro.models import ibert as ib

    cfg1, qp = _one_layer_setup()
    fwd = jax.jit(
        lambda t: ib.ibert_int_forward(qp, cfg1, t, impl="ref").values,
        static_argnames=())
    t_by_seq = {}
    for s in SEQ_LENS:
        toks = jax.random.randint(jax.random.PRNGKey(s), (1, s), 0,
                                  cfg1.vocab_size)
        f = jax.jit(lambda t: ib.ibert_int_forward(
            qp, cfg1, t, impl="ref").values)
        t_by_seq[s] = _median_time(f, toks)
        row(f"table1_encoder_T_seq{s}", t_by_seq[s] * 1e6,
            f"X_est={0.5325 * t_by_seq[s] * 1e6:.1f}us")
    state["t_by_seq"] = t_by_seq


def table2_full_model_eq1(state: Dict) -> None:
    """Paper Table 2 analogue: Eq.1 with L=12 encoders, d=1.1us hop."""
    from repro.core.latency_model import StageTiming, total_latency

    t_by_seq = state["t_by_seq"]
    est = {}
    for s, t in t_by_seq.items():
        est[s] = total_latency(StageTiming(T=t, X=0.5325 * t, d=1.1e-6), 12)
        row(f"table2_ibert12_eq1_seq{s}", est[s] * 1e6,
            "T+(L-1)(X+d), L=12")
    state["eq1"] = est


def table3_padding_vs_nopadding(state: Dict) -> None:
    """Paper Table 3 analogue: GLUE avg len 38 unpadded vs padded-to-128."""
    from repro.core.packing import bucket_len

    t_by_seq = state["t_by_seq"]
    padded = t_by_seq[128]
    bucket = bucket_len(38, buckets=SEQ_LENS)  # -> 64
    nopad = t_by_seq[bucket]
    row("table3_latency_padded128", padded * 1e6, "per encoder")
    row("table3_latency_nopad_len38", nopad * 1e6,
        f"bucket={bucket}, speedup={padded / nopad:.2f}x "
        f"(paper: 7.19/2.58=2.79x)")
    state["padded"], state["nopad"] = padded, nopad


def table4_throughput(state: Dict) -> None:
    """Paper Table 4/5 analogue: pipeline steady-state throughput = 1/T."""
    from repro.core.latency_model import StageTiming, throughput

    thr_pad = throughput(StageTiming(T=state["padded"], X=0, d=0))
    thr_nopad = throughput(StageTiming(T=state["nopad"], X=0, d=0))
    row("table4_throughput_padded", 1e6 / thr_pad,
        f"{thr_pad:.1f} inf/s")
    row("table4_throughput_nopad", 1e6 / thr_nopad,
        f"{thr_nopad:.1f} inf/s, gain {thr_nopad / thr_pad:.2f}x "
        "(paper: no-padding 6802 vs 4121 = 1.65x)")


def table5_accelerator_comparison(state: Dict) -> None:
    """Paper Table 3/5 comparison row: our v5e roofline estimate vs the
    paper's published numbers (T4 1.66ms, A100 0.77ms, NPE 13.96ms,
    paper-FPGA 2.58ms no-padding batch-1 latency)."""
    est = state.get("v5e_latency")
    if est is None:
        sec9_v5e_estimate(state)
        est = state["v5e_latency"]
    for name, ms in (("NVIDIA_T4", 1.66), ("NVIDIA_A100", 0.77),
                     ("NPE_FPGA", 13.96), ("paper_6FPGA_nopad", 2.58)):
        row(f"table5_published_{name}", ms * 1e3, "paper-reported")
    row("table5_ours_v5e_roofline", est * 1e6,
        f"speedup vs A100 {0.77e-3 / est:.2f}x (estimate)")


def fig15_cluster_resources(state: Dict) -> None:
    """Fig. 15 analogue: per-cluster kernel counts & routing-table sizes."""
    from repro.configs import get_config
    from repro.core.cluster_builder import build_topology

    for arch in ("ibert-base", "deepseek-coder-33b", "moonshot-v1-16b-a3b"):
        topo = build_topology(get_config(arch))
        kmax = max(len(c.kernels) for c in topo.clusters)
        row(f"fig15_{arch}_kernels_per_cluster", kmax,
            f"clusters={len(topo.clusters)}, total={topo.total_kernels}, "
            f"routes/device={topo.routing_entries_per_device()} "
            f"(flat would be {topo.routing_entries_flat()})")


def sec9_v5e_estimate(state: Dict) -> None:
    """§9 analogue: analytic projection of the I-BERT encoder onto TPU v5e
    int8 (the paper does this for Versal AIEs and lands at 860us vs A100's
    770us)."""
    from repro.configs import get_config
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS_INT8

    cfg = get_config("ibert-base")
    s, d, f = 128, cfg.d_model, cfg.d_ff
    per_layer_flops = 2 * s * d * (3 * d) + 2 * s * s * d * 2 \
        + 2 * s * d * d + 2 * s * d * f * 2
    total_flops = per_layer_flops * cfg.n_layers
    weight_bytes = cfg.n_layers * (4 * d * d + 2 * d * f)  # int8
    compute_s = total_flops / PEAK_FLOPS_INT8
    memory_s = weight_bytes / HBM_BW
    est = max(compute_s, memory_s)
    state["v5e_latency"] = est
    row("sec9_v5e_ibert_estimate", est * 1e6,
        f"compute={compute_s * 1e6:.1f}us mem={memory_s * 1e6:.1f}us "
        f"(paper Versal est: 860us, A100: 770us)")


def gmi_collective_models(state: Dict) -> None:
    """§4/§5 analogue: link-byte models for a 1 MiB payload per device.

    composed AllReduce (Reduce->Broadcast via root, the paper's composition)
    vs fused ring vs gateway-hierarchical across 2 pods."""
    size = 2 ** 20
    n_intra, n_pods = 256, 2
    composed = 2 * size * n_intra  # root receives N, then sends N copies
    ring = 2 * size * (n_intra - 1) / n_intra  # reduce-scatter + all-gather
    flat_inter = 2 * size * (n_intra * n_pods - 1) / (n_intra * n_pods)
    gateway = ring + (size / n_intra) * 2  # intra RS/AG + leader exchange
    row("gmi_allreduce_composed_bytes", composed / 1e3,
        "bytes(KB) at root link — the paper-faithful Gather->Bcast")
    row("gmi_allreduce_ring_bytes", ring / 1e3, "fused 1-pod ring")
    row("gmi_allreduce_flat_2pod_bytes", flat_inter / 1e3,
        "flat 512-chip ring: every step crosses the pod boundary")
    row("gmi_allreduce_gateway_2pod_bytes", gateway / 1e3,
        f"hierarchical: inter-pod carries 1/{n_intra} of payload "
        "(the clusters-of-clusters gateway rule)")


def bench_int8_kernels(state: Dict) -> None:
    """Kernel microbench: int8 GEMM + i-ops wall time (interpret/oracle)."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (128, 768)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (768, 768)), jnp.int8)
    t = _median_time(lambda: ops.int8_matmul(
        a, b, jnp.float32(0.01), jnp.float32(0.01), impl="ref"))
    row("kernel_int8_matmul_128x768x768", t * 1e6, "oracle path")
    x = jnp.asarray(rng.integers(-2047, 2047, (128, 128)), jnp.int32)
    t = _median_time(lambda: ops.i_softmax(x, jnp.float32(0.01), impl="ref"))
    row("kernel_i_softmax_128x128", t * 1e6, "")


def serve_cb(state: Dict) -> None:
    """§8.2 analogue: wave vs continuous-batching scheduling on a mixed
    prompt-length / mixed decode-budget request stream, plus the fused
    decode fast path (horizon-n `Model.decode_steps`) against the
    one-dispatch-per-token scheduler (the PR 1 engine) at equal outputs.

    All engines run *dense slot* caches here so the comparison isolates
    scheduling (waves vs slots vs horizon) exactly as before the paged
    pool landed — the paged-vs-dense measurement is `serve_paged`
    (`--shared-prefix`), which pins one impl for its stream-equality
    assertion."""
    import jax as _jax
    from repro.configs import get_config
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine, WaveEngine
    from repro.serving.stream import poisson_requests, replay

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    # decode-bound budgets (the regime the fused path targets) on a hot
    # Poisson ingress — the paper's line-rate feed, where waves also pay
    # their deadline-batching idle time
    stream = poisson_requests(np.random.default_rng(0), 24, cfg.vocab_size,
                              len_range=(4, 28), budgets=(32, 97), rate=400.0)

    results, metrics, streams, predicted = {}, {}, {}, {}
    setups = (
        ("wave", WaveEngine, {}),
        ("cb_step", ContinuousBatchingEngine,
         {"decode_horizon": 1, "paged": False}),
        ("cb", ContinuousBatchingEngine, {"paged": False}),
    )
    for name, cls, kw in setups:
        eng = cls(model, params, max_batch=4, buckets=(16, 32),
                  max_decode_len=96, **kw)
        replay(eng, stream, warmup=False)  # compile pass
        steps0 = eng.stats["decode_steps"]
        disp0 = eng.stats["decode_dispatches"]
        passes = []  # median of 3 measured passes (CPU box is noisy)
        for _ in range(3):
            passes.append(replay(eng, stream, warmup=False))
        done, wall, tok_s, ttft = sorted(passes, key=lambda p: p[1])[1]
        if name == "cb":  # the predicted-vs-measured stamp (perf.yml band)
            predicted["cb"] = _predicted_entry(
                _calibrate_engine(eng), eng, done, tok_s)
        results[name] = tok_s
        streams[name] = {r.rid: tuple(r.tokens_out) for r in done}
        toks = sum(len(r.tokens_out) for r in done)
        disp_tok = (eng.stats["decode_dispatches"] - disp0) / 3 / toks
        metrics[name] = {
            "tok_s": round(tok_s, 2),
            "ttft_p50_ms": round(float(np.percentile(ttft, 50)), 3),
            "ttft_p95_ms": round(float(np.percentile(ttft, 95)), 3),
            "dispatches_per_token": round(disp_tok, 4),
            "decode_horizon": eng.decode_horizon,
        }
        row(f"serve_{name}_per_token", wall / toks * 1e6,
            f"{tok_s:.1f}tok/s ttft_p50={np.percentile(ttft, 50):.1f}ms "
            f"ttft_p95={np.percentile(ttft, 95):.1f}ms "
            f"disp/tok={disp_tok:.3f} "
            f"decode_steps={(eng.stats['decode_steps'] - steps0) // 3}")
    assert streams["cb"] == streams["cb_step"], \
        "fused horizon decode must be bit-identical to single-step"
    row("serve_cb_vs_wave_speedup", results["cb"] / results["wave"],
        "continuous-batching tok/s over wave tok/s (>=1 expected)")
    fused_speedup = results["cb"] / results["cb_step"]
    disp_drop = (metrics["cb_step"]["dispatches_per_token"]
                 / max(metrics["cb"]["dispatches_per_token"], 1e-9))
    row("serve_fused_vs_single_step_speedup", fused_speedup,
        f"horizon-8 tok/s over one-dispatch-per-token (>=1.3 target), "
        f"dispatches/token drop {disp_drop:.1f}x (>=4 target), "
        "token streams bit-identical")
    state["serve_cb_speedup"] = results["cb"] / results["wave"]
    from repro.core.plan_search import PREDICTION_BAND
    state.setdefault("bench_json", {})["serve_cb"] = {
        "engines": metrics,
        "fused_vs_single_step_tok_s": round(fused_speedup, 3),
        "dispatches_per_token_drop": round(disp_drop, 2),
        "streams_bit_identical": True,
        # popped like _run_meta by the gate/diff; the band step reads it
        "_predicted": dict(predicted, band=list(PREDICTION_BAND)),
    }


def _measure_cb_engine(eng, stream, reps: int = 3):
    """Shared serving-engine measurement harness (serve_paged/serve_quant):
    one unmeasured compile pass, `reps` measured replays, median-by-wall
    pick.  Returns (median_pass, per-pass stream dicts, core metrics) where
    median_pass = (done, wall_s, tok_s, ttft_ms list)."""
    from repro.serving.stream import replay

    replay(eng, stream, warmup=False)  # compile pass
    disp0 = eng.stats["decode_dispatches"]
    steps0 = eng.stats["decode_steps"]
    lanes0 = eng.stats["active_lane_steps"]
    passes = [replay(eng, stream, warmup=False) for _ in range(reps)]
    median = sorted(passes, key=lambda p: p[1])[reps // 2]
    done, wall, tok_s, ttft = median
    toks = sum(len(r.tokens_out) for r in done)
    disp_tok = (eng.stats["decode_dispatches"] - disp0) / reps / toks
    conc = ((eng.stats["active_lane_steps"] - lanes0)
            / max(eng.stats["decode_steps"] - steps0, 1))
    metrics = {
        "tok_s": round(tok_s, 2),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)), 3),
        "ttft_p95_ms": round(float(np.percentile(ttft, 95)), 3),
        "dispatches_per_token": round(disp_tok, 4),
        "sustained_concurrency": round(conc, 2),
    }
    streams = [{r.rid: tuple(r.tokens_out) for r in p[0]} for p in passes]
    return median, streams, metrics


def serve_paged(state: Dict) -> None:
    """The `--shared-prefix` workload: paged KV + radix prefix reuse vs the
    dense-slot engine at *equal KV HBM* on a shared-system-prompt stream
    (`serving/stream.shared_prefix_requests`).

    The dense engine reserves a worst-case slot row per lane, so a fixed KV
    budget caps it at `dense_batch` lanes; the paged engine spends the same
    bytes as a page pool, where prefix sharing + actual-length allocation
    fit ~2x the lanes, and prefix-hit admissions skip prefill entirely.
    Streams must be bit-identical (one pinned impl, and the forced-token
    suffix ingest writes exactly the KV a cold prefill would)."""
    import jax as _jax
    from repro.configs import get_config
    from repro.kernels import ops as kops
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.stream import shared_prefix_requests

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    stream = shared_prefix_requests(np.random.default_rng(0), 24,
                                    cfg.vocab_size, prefix_len=48,
                                    suffix_range=(3, 9), budgets=(16, 48),
                                    rate=300.0)
    page_size = 16
    dense_batch = 4
    buckets, max_decode = (64,), 96
    kv_rows = dense_batch * (max(buckets) + max_decode)  # dense KV budget
    setups = (
        ("dense_slots", dict(paged=False, max_batch=dense_batch)),
        # same KV bytes, spent as a shared page pool: 2x the lanes
        ("paged", dict(max_batch=2 * dense_batch, page_size=page_size,
                       num_pages=kv_rows // page_size + 1)),
    )
    metrics, streams = {}, {}
    with kops.pinned_impl("ref"):
        for name, kw in setups:
            eng = ContinuousBatchingEngine(
                model, params, buckets=buckets, max_decode_len=max_decode,
                **kw)
            (done, wall, tok_s, ttft), streams[name], metrics[name] = \
                _measure_cb_engine(eng, stream)
            toks = sum(len(r.tokens_out) for r in done)
            metrics[name]["max_batch"] = eng.max_batch
            if eng.paged:
                metrics[name].update(
                    prefix_hits=eng.stats["prefix_hits"],
                    prefix_hit_tokens=eng.stats["prefix_hit_tokens"],
                    prefills=eng.stats["prefills"],
                    pages_peak=eng.stats["pages_peak"],
                    preemptions=eng.stats["preemptions"])
            row(f"serve_paged_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s "
                f"conc={metrics[name]['sustained_concurrency']:.2f} "
                f"ttft_p50={np.percentile(ttft, 50):.1f}ms "
                f"disp/tok={metrics[name]['dispatches_per_token']:.3f}")
    for k in range(3):  # every pass: cold tree on 1, warm prefix cache after
        assert streams["dense_slots"][k] == streams["paged"][k], \
            f"paged stream diverged from dense slots on pass {k}"
    speedup = metrics["paged"]["tok_s"] / metrics["dense_slots"]["tok_s"]
    conc_gain = (metrics["paged"]["sustained_concurrency"]
                 / max(metrics["dense_slots"]["sustained_concurrency"], 1e-9))
    row("serve_paged_vs_dense_tok_s", speedup,
        "paged tok/s over dense slots at equal KV HBM (>=1.3 target)")
    row("serve_paged_vs_dense_concurrency", conc_gain,
        "sustained concurrent requests, paged/dense (>=1.5 target)")
    state.setdefault("bench_json", {})["serve_paged"] = {
        "engines": metrics,
        "paged_vs_dense_tok_s": round(speedup, 3),
        "paged_vs_dense_concurrency": round(conc_gain, 3),
        "streams_bit_identical": True,
    }


def serve_quant(state: Dict) -> None:
    """The `--kv-dtype int8` axis: quantized KV-cache pages vs the bf16
    paged engine at *equal KV HBM*.

    int8 pages store ~(hd+4)/(2*hd) of the bf16 bytes per cache row
    (values at 1 B + one f32 scale per row per kv head), so the same byte
    budget buys ~1.6-2x the pages — sized here via
    `serving.engine.kv_page_bytes` — and the int8 engine sustains more
    resident lanes on a pool-bound stream.  Accuracy is measured as the
    greedy token-match rate against the bf16 engine's streams
    (>=0.99 gated): the model is first fitted to the affine-cycle task
    (models/synthetic.py) because stream agreement is only a meaningful
    instrument on a model with a confident predictive distribution —
    random-init top-2 logit gaps cluster at zero and *any* numeric
    difference, including bf16 summation order, flips tokens.
    """
    from repro.configs import get_config
    from repro.kernels import ops as kops
    from repro.models.synthetic import affine_prompts, fit_affine_lm
    from repro.models.transformer import make_model
    from repro.serving.engine import (
        ContinuousBatchingEngine, Request, kv_page_bytes,
    )

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = fit_affine_lm(model)  # cached per process; ~1k adam steps
    rng = np.random.default_rng(0)
    prompts = affine_prompts(rng, 24, cfg.vocab_size, len_range=(6, 20))
    buds = rng.integers(16, 48, len(prompts))
    gaps = rng.exponential(1.0 / 300.0, len(prompts))
    arrivals = np.cumsum(gaps)
    stream = [Request(rid=i, prompt=p, max_new_tokens=int(buds[i]),
                      t_arrival=float(arrivals[i]))
              for i, p in enumerate(prompts)]

    page_size, bf16_pages = 16, 16  # pool-bound at ~4 resident lanes
    budget_bytes = bf16_pages * kv_page_bytes(cfg, page_size, "bf16")
    setups = []
    for name in ("bf16", "int8"):
        n_pages = budget_bytes // kv_page_bytes(cfg, page_size, name)
        setups.append((name, dict(kv_dtype=name, page_size=page_size,
                                  num_pages=int(n_pages) + 1)))
    metrics, streams = {}, {}
    with kops.pinned_impl("ref"):
        for name, kw in setups:
            eng = ContinuousBatchingEngine(
                model, params, max_batch=8, buckets=(32,),
                max_decode_len=64, **kw)
            (done, wall, tok_s, ttft), passes, metrics[name] = \
                _measure_cb_engine(eng, stream)
            streams[name] = passes[-1]  # greedy: identical across passes
            toks = sum(len(r.tokens_out) for r in done)
            metrics[name].update(
                num_pages=eng.pool.num_pages,
                pages_peak=eng.stats["pages_peak"],
                preemptions=eng.stats["preemptions"])
            row(f"serve_quant_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s "
                f"conc={metrics[name]['sustained_concurrency']:.2f} "
                f"pages={eng.pool.num_pages} "
                f"ttft_p50={np.percentile(ttft, 50):.1f}ms")
    tot = sum(len(v) for v in streams["bf16"].values())
    matched = sum(
        sum(a == b for a, b in zip(streams["bf16"][rid], streams["int8"][rid]))
        for rid in streams["bf16"])
    match_rate = matched / max(tot, 1)
    speedup = metrics["int8"]["tok_s"] / metrics["bf16"]["tok_s"]
    conc_gain = (metrics["int8"]["sustained_concurrency"]
                 / max(metrics["bf16"]["sustained_concurrency"], 1e-9))
    row("serve_quant_int8_vs_bf16_tok_s", speedup,
        "int8 tok/s over bf16 paged at equal KV HBM (>=1.2 target)")
    row("serve_quant_int8_vs_bf16_concurrency", conc_gain,
        "sustained concurrent requests, int8/bf16 (>=1.5 target)")
    row("serve_quant_token_match_rate", match_rate,
        f"{matched}/{tot} greedy tokens identical to bf16 (>=0.99 gated)")
    state.setdefault("bench_json", {})["serve_quant"] = {
        "engines": metrics,
        "int8_vs_bf16_tok_s": round(speedup, 3),
        "int8_vs_bf16_concurrency": round(conc_gain, 3),
        "token_match_rate": round(match_rate, 4),
        "equal_kv_hbm_bytes": int(budget_bytes),
    }


def serve_sharded(state: Dict) -> None:
    """The tentpole's measurement: a `mode="serve"` plan on the forced
    multi-device host mesh (CI: XLA_FLAGS=--xla_force_host_platform_
    device_count=8) sharding the paged arena's kv-head dim over `model`,
    vs the single-device paged engine on the same shared-prefix stream.

    On fake host-platform devices the sharded path is *slower* (8 CPU
    "devices" share one socket and every gather is a real copy), so the
    gated ratio `sharded_vs_single_tok_s` is an overhead floor, not a
    speedup claim — the quantity that transfers is `token_match_rate`,
    gated at the absolute floor: sharded serving must be BIT-IDENTICAL
    to single-device (the serve plan's gather-form TP + shard_map'd
    paged decode make every cross-device reduction exact).
    """
    import dataclasses

    import jax as _jax
    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.stream import shared_prefix_requests

    n_dev = _jax.device_count()
    if n_dev < 2:
        row("serve_sharded_skipped", 0.0,
            "needs a multi-device host platform (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before jax init); "
            "gated keys omitted from this run")
        # drop the bench from the gate scope too: a single-device sweep
        # with --check-against must not fail on the baseline's
        # serve_sharded section it declared itself unable to measure
        state.setdefault("skipped", set()).add("serve_sharded")
        return
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_heads=8, n_kv_heads=8)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    stream = shared_prefix_requests(np.random.default_rng(0), 16,
                                    cfg.vocab_size, prefix_len=48,
                                    suffix_range=(3, 9), budgets=(8, 24),
                                    rate=300.0)
    mesh = make_mesh((1, n_dev), ("data", "model"))
    state.setdefault("meshes", {})["serve_sharded"] = dict(mesh.shape)
    setups = (("single", None),
              ("sharded", build_plan(cfg, mesh, mode="serve")))
    metrics, streams, predicted = {}, {}, {}
    with kops.pinned_impl("ref"):
        for name, plan in setups:
            eng = ContinuousBatchingEngine(
                model, params, max_batch=4, buckets=(64,),
                max_decode_len=32, plan=plan)
            (done, wall, tok_s, ttft), streams[name], metrics[name] = \
                _measure_cb_engine(eng, stream)
            predicted[name] = _predicted_entry(
                _calibrate_engine(eng), eng, done, tok_s)
            toks = sum(len(r.tokens_out) for r in done)
            metrics[name].update(prefix_hits=eng.stats["prefix_hits"])
            row(f"serve_sharded_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s devices={n_dev if plan else 1} "
                f"ttft_p50={np.percentile(ttft, 50):.1f}ms "
                f"hits={eng.stats['prefix_hits']}")
    tot = matched = 0
    for k in range(len(streams["single"])):  # every measured pass
        for rid, ts in streams["single"][k].items():
            tot += len(ts)
            matched += sum(a == b
                           for a, b in zip(ts, streams["sharded"][k][rid]))
    match_rate = matched / max(tot, 1)
    ratio = metrics["sharded"]["tok_s"] / metrics["single"]["tok_s"]
    row("serve_sharded_vs_single_tok_s", ratio,
        f"{n_dev}-way host-platform mesh overhead floor (fake devices "
        "share one socket; the ratio is gated so the sharded path can't "
        "silently rot)")
    row("serve_sharded_token_match_rate", match_rate,
        f"{matched}/{tot} tokens identical to single-device "
        "(bit-identity gated at the 0.99 absolute floor; expected 1.0)")
    from repro.core.plan_search import PREDICTION_BAND
    state.setdefault("bench_json", {})["serve_sharded"] = {
        "engines": metrics,
        "devices": n_dev,
        "sharded_vs_single_tok_s": round(ratio, 3),
        "token_match_rate": round(match_rate, 4),
        "_predicted": dict(predicted, band=list(PREDICTION_BAND)),
    }


def serve_throughput(state: Dict) -> None:
    """The throughput-mode tentpole: an exact=False ``serve_pipeline``
    plan (request-skewed schedule over stage-local paged arenas) vs the
    exact drained pipeline on the same stream, both on the forced
    multi-device host mesh (CI: XLA_FLAGS=--xla_force_host_platform_
    device_count=8).

    The exact schedule drains 2S-1 ticks per decode step (S lane
    microbatches + S-1 bubble); the skewed schedule keeps every stage on
    a different lane group's decode step and amortizes to S ticks per
    step — an asymptotic (2S-1)/S upper bound (1.875x at S=8), realized
    at 1.2-1.4x after drain ramp + paged-arena overhead (baseline-banded
    like every ratio).  Unlike every other
    serving bench this one is NOT bit-exact by contract: the skewed
    schedule reorders admissions across lane groups, so streams are
    gated by a token-match band (>=0.98, docs/serving.md §exactness
    contract) instead of equality — with the pinned ref kernels the
    observed rate is still 1.0 on this stream.
    """
    import dataclasses

    import jax as _jax
    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.stream import poisson_requests

    n_dev = _jax.device_count()
    if n_dev < 2:
        row("serve_throughput_skipped", 0.0,
            "needs a multi-device host platform (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before jax init); "
            "gated keys omitted from this run")
        state.setdefault("skipped", set()).add("serve_throughput")
        return
    n_stages = n_dev
    # four layer periods per stage: with a single tiny layer per stage
    # the per-tick dispatch overhead (collectives are real host copies)
    # swamps the schedule, and the bench would measure XLA fixed costs
    # rather than the drain bubble the skew schedule removes
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=4 * n_stages)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    mesh = make_mesh((n_stages,), ("stage",))
    state.setdefault("meshes", {})["serve_throughput"] = dict(mesh.shape)
    # decode-bound budgets: the skewed schedule's win is steady-state
    # ticks-per-step, so deep decodes amortize its S-1 drain ramp
    stream = poisson_requests(np.random.default_rng(0), 16, cfg.vocab_size,
                              len_range=(4, 14), budgets=(16, 33),
                              rate=300.0)
    setups = (
        ("exact", build_plan(cfg, mesh, mode="serve_pipeline", exact=True),
         dict(paged=False)),
        ("skewed", build_plan(cfg, mesh, mode="serve_pipeline", exact=False),
         dict(page_size=8)),
    )
    metrics, streams = {}, {}
    with kops.pinned_impl("ref"):
        for name, plan, kw in setups:
            eng = ContinuousBatchingEngine(
                model, params, max_batch=n_stages, buckets=(16,),
                max_decode_len=40, plan=plan, **kw)
            (done, wall, tok_s, ttft), streams[name], metrics[name] = \
                _measure_cb_engine(eng, stream)
            toks = sum(len(r.tokens_out) for r in done)
            metrics[name]["paged"] = eng.paged
            row(f"serve_throughput_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s stages={n_stages} paged={eng.paged} "
                f"ttft_p50={np.percentile(ttft, 50):.1f}ms "
                f"disp/tok={metrics[name]['dispatches_per_token']:.3f}")
    tot = matched = 0
    for k in range(len(streams["exact"])):  # every measured pass
        for rid, ts in streams["exact"][k].items():
            tot += len(ts)
            matched += sum(a == b
                           for a, b in zip(ts, streams["skewed"][k][rid]))
    match_rate = matched / max(tot, 1)
    ratio = metrics["skewed"]["tok_s"] / metrics["exact"]["tok_s"]
    row("serve_throughput_vs_exact_tok_s", ratio,
        f"request-skewed tok/s over the exact drained pipeline on the "
        f"{n_stages}-stage host mesh (>=1.2 target; ceiling "
        f"{(2 * n_stages - 1) / n_stages:.2f}x)")
    row("serve_throughput_token_match_rate", match_rate,
        f"{matched}/{tot} tokens identical to the exact pipeline "
        "(band floor 0.98 — NOT an equality gate; docs/serving.md "
        "§exactness contract)")
    state.setdefault("bench_json", {})["serve_throughput"] = {
        "engines": metrics,
        "devices": n_dev,
        "stages": n_stages,
        "throughput_vs_exact_tok_s": round(ratio, 3),
        "token_match_rate": round(match_rate, 4),
    }


def serve_spec(state: Dict) -> None:
    """The `--draft-config` axis: greedy speculative decoding vs the plain
    fused-scan paged engine on a decode-bound stream.

    A 1-layer draft proposes up to `spec_k` tokens per lane inside one
    dispatch; the target verifies all k+1 positions in a single batched
    pass (contiguous-query paged attention) and the accepted prefix lands
    through the forced-token queue, so a dispatch can emit up to k+1
    tokens for ~one target forward.  Draft/target agreement is the whole
    game, so both are *fitted* affine-cycle LMs (models/synthetic.py):
    the high-agreement draft trains on the same corpus as the target, the
    mid-agreement draft trains on a corpus deviated at every value ≡ 0
    (mod 3) (`fit_affine_lm(..., disagree_every=3)`), dialing acceptance
    down and exercising the per-lane depth ladder.  Verification is
    lossless for greedy decoding — every emitted token is the target's
    own argmax — so `token_match_rate` is gated at the absolute floor and
    expected to be exactly 1.0 for BOTH drafts.
    """
    from repro.configs import get_config
    from repro.kernels import ops as kops
    from repro.models.synthetic import affine_prompts, fit_affine_lm
    from repro.models.transformer import make_model
    from repro.serving.engine import ContinuousBatchingEngine, Request

    # 8 layers: deep enough that the 1-layer draft is genuinely cheap
    # relative to the target (on the 2-layer reduced stack a draft step
    # costs nearly a target step and speculation cannot win anywhere);
    # the cb baseline below serves the *same* target, so the gated ratio
    # compares engines, not model sizes
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              name="smollm-135m-spec-target", n_layers=8)
    model = make_model(cfg, remat=False)
    # 3k steps: the 8-layer stack underfits at the 1k default and its
    # noisier stream drags draft agreement (and so acceptance) down
    params = fit_affine_lm(model, steps=3000)
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    draft = make_model(dcfg, remat=False)
    dparams_hi = fit_affine_lm(draft)  # same corpus -> high agreement
    dparams_mid = fit_affine_lm(draft, disagree_every=3)

    rng = np.random.default_rng(0)
    # decode-bound: short prompts, deep budgets — the regime where drafted
    # tokens amortize target forwards instead of prefill dominating
    prompts = affine_prompts(rng, 12, cfg.vocab_size, len_range=(6, 20))
    buds = rng.integers(24, 48, len(prompts))
    arrivals = np.cumsum(rng.exponential(1.0 / 300.0, len(prompts)))
    stream = [Request(rid=i, prompt=p, max_new_tokens=int(buds[i]),
                      t_arrival=float(arrivals[i]))
              for i, p in enumerate(prompts)]

    base_kw = dict(max_batch=4, buckets=(32,), max_decode_len=96,
                   page_size=16)
    setups = (
        ("cb", {}),
        ("spec", dict(spec_config=dict(
            draft_model=draft, draft_params=dparams_hi, spec_k=8))),
        ("spec_disagree", dict(spec_config=dict(
            draft_model=draft, draft_params=dparams_mid, spec_k=8))),
    )
    metrics, streams = {}, {}
    with kops.pinned_impl("ref"):
        for name, kw in setups:
            eng = ContinuousBatchingEngine(model, params, **base_kw, **kw)
            (done, wall, tok_s, ttft), streams[name], metrics[name] = \
                _measure_cb_engine(eng, stream)
            toks = sum(len(r.tokens_out) for r in done)
            extra = ""
            if eng.spec:
                acc = (eng.stats["spec_accepted"]
                       / max(eng.stats["spec_proposed"], 1))
                metrics[name].update(
                    acceptance=round(acc, 3),
                    spec_dispatches=eng.stats["spec_dispatches"],
                    draft_prefills=eng.stats["spec_draft_prefills"],
                    catchup_tokens=eng.stats["spec_catchup_tokens"])
                extra = f" accept={acc:.2f}"
            row(f"serve_spec_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s "
                f"disp/tok={metrics[name]['dispatches_per_token']:.3f} "
                f"ttft_p50={np.percentile(ttft, 50):.1f}ms" + extra)
    # losslessness: every spec stream (any draft, any acceptance) must be
    # bit-identical to the plain engine's greedy streams, every pass
    tot = matched = 0
    for name in ("spec", "spec_disagree"):
        for k in range(len(streams["cb"])):
            for rid, ts in streams["cb"][k].items():
                tot += len(ts)
                matched += sum(a == b
                               for a, b in zip(ts, streams[name][k][rid]))
    match_rate = matched / max(tot, 1)
    speedup = metrics["spec"]["tok_s"] / metrics["cb"]["tok_s"]
    row("serve_spec_vs_cb_tok_s", speedup,
        "speculative tok/s over plain fused-scan cb at high draft "
        "agreement (>=1.3 target)")
    row("serve_spec_token_match_rate", match_rate,
        f"{matched}/{tot} greedy tokens identical to the plain engine "
        "(lossless verification; gated floor 0.99, expected exactly 1.0)")
    state.setdefault("bench_json", {})["serve_spec"] = {
        "engines": metrics,
        "spec_vs_cb_tok_s": round(speedup, 3),
        "token_match_rate": round(match_rate, 4),
    }


def serve_fleet(state: Dict) -> None:
    """Fleet routing policy comparison (docs/fleet.md): N independent
    engine replicas behind the FleetRouter serving a multi-tenant
    shared-system-prompt Poisson stream, affinity dispatch vs the
    round-robin control arm.

    Affinity routes every request of one prefix group to the replica
    whose radix tree holds that prefix, so the fleet pays ONE cold
    prefill per prefix; round-robin spreads each group over all replicas
    and pays up to one cold prefill per (replica, prefix) pair.  The
    gated quantities are the affinity/round-robin ratios of aggregate
    prefix_hit_tokens and tok/s — placement quality, not parallel
    speedup: in-process replicas drain sequentially on the host, so the
    wall-clock difference is exactly the skipped prefill work.

    Every measured pass uses FRESH prefixes (same shape, new tokens):
    replicas keep their radix trees between passes, and replaying one
    stream would let round-robin's second pass hit prefixes its first
    pass seeded on every replica, converging the two policies.

    Per-request token streams must be identical to a single plain engine
    serving the same stream (the fleet only chooses *where* a request
    runs), so token_match_rate is gated at the bit-identity floor and
    expected exactly 1.0."""
    import jax as _jax
    from repro.configs import get_config
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.router import FleetConfig, build_fleet
    from repro.serving.stream import multi_prefix_requests, replay

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    n_replicas, n_prefixes, n_req, reps = 3, 8, 32, 3

    rng = np.random.default_rng(0)

    def mk_stream():
        # heavy system prompts (bucket-256 prefill) over short chat turns:
        # the regime where the cold-prefill bill the router routes around
        # dominates — a prefix hit saves a 256-token prefill and pays only
        # a handful of forced-token suffix steps
        return multi_prefix_requests(rng, n_req, cfg.vocab_size,
                                     n_prefixes=n_prefixes, prefix_len=240,
                                     suffix_range=(2, 6), budgets=(3, 7),
                                     rate=1500.0)

    # streams[0] warms compilation x2 (cold prefill + hit-admission
    # paths); streams[1] is a discarded fresh-prefix pass (warms the
    # admission-batch shapes each policy's steady state actually hits);
    # streams[2..reps+1] are the measured passes
    pass_streams = [mk_stream() for _ in range(reps + 2)]

    # pool sized so each pass's prefixes always fit (stale passes evict
    # first under LRU) — hit counts stay structural, not pressure-timing.
    # rebalance_margin is set high so the affinity arm measures pure
    # placement; the deadline-aware override is exercised in tests.
    engine_kw = dict(max_batch=4, buckets=(16, 32, 64, 256), num_pages=192)
    systems = (
        ("affinity", build_fleet(model, params, n_replicas,
                                 config=FleetConfig(route="affinity",
                                                    rebalance_margin=10_000),
                                 **engine_kw)),
        ("round_robin", build_fleet(model, params, n_replicas,
                                    config=FleetConfig(route="round-robin"),
                                    **engine_kw)),
        ("single", ContinuousBatchingEngine(model, params, **engine_kw)),
    )

    metrics, streams = {}, {}
    for name, sys_ in systems:
        is_fleet = hasattr(sys_, "replicas")

        def snap():
            st = sys_.stats() if is_fleet else sys_.stats
            return (st["prefix_hits"], st["prefix_hit_tokens"],
                    (sum(p["prefills"] for p in st["replicas"])
                     if is_fleet else st["prefills"]))
        replay(sys_, pass_streams[0], warmup=False)  # compile, cold paths
        replay(sys_, pass_streams[0], warmup=False)  # compile, hit paths
        replay(sys_, pass_streams[1], warmup=False)  # fresh-prefix warm
        hits0, hit_tok0, pre0 = snap()
        passes, per_pass = [], []
        for p in range(2, reps + 2):
            done, wall, tok_s, _ = replay(sys_, pass_streams[p],
                                          warmup=False)
            passes.append((done, wall, tok_s))
            per_pass.append({r.rid: tuple(r.tokens_out) for r in done})
        hits, hit_tok, prefills = (b - a for a, b in zip((hits0, hit_tok0,
                                                          pre0), snap()))
        done, wall, tok_s = sorted(passes, key=lambda p: p[1])[reps // 2]
        toks = sum(len(r.tokens_out) for r in done)
        streams[name] = per_pass
        metrics[name] = {
            "tok_s": round(tok_s, 2),
            "prefix_hits": int(hits),
            "prefix_hit_tokens": int(hit_tok),
            "prefills": int(prefills),
        }
        if is_fleet:
            metrics[name]["by_kind"] = dict(
                sorted(sys_.stats()["by_kind"].items()))
        row(f"serve_fleet_{name}_per_token", wall / toks * 1e6,
            f"{tok_s:.1f}tok/s hit_tokens={hit_tok} "
            f"prefills={prefills} over {reps} fresh-prefix passes")

    tot = matched = 0
    for p in range(reps):
        for arm in ("affinity", "round_robin"):
            for rid, ts in streams["single"][p].items():
                tot += len(ts)
                matched += sum(a == b
                               for a, b in zip(ts, streams[arm][p][rid]))
    match_rate = matched / max(tot, 1)
    hit_ratio = (metrics["affinity"]["prefix_hit_tokens"]
                 / max(metrics["round_robin"]["prefix_hit_tokens"], 1))
    tok_ratio = (metrics["affinity"]["tok_s"]
                 / metrics["round_robin"]["tok_s"])
    row("serve_fleet_affinity_vs_rr_hit_tokens", hit_ratio,
        f"{n_replicas} replicas, {n_prefixes} prefix groups: affinity "
        "prefix_hit_tokens over round-robin (>1 expected — one cold "
        "prefill per prefix vs per replica x prefix)")
    row("serve_fleet_affinity_vs_rr_tok_s", tok_ratio,
        "affinity tok/s over round-robin on the same stream (>1 expected "
        "— the skipped cold prefills; sequential drain, docs/fleet.md)")
    row("serve_fleet_token_match_rate", match_rate,
        f"{matched}/{tot} fleet tokens identical to the single plain "
        "engine (placement-only routing; gated floor 0.99, expected "
        "exactly 1.0)")
    state.setdefault("bench_json", {})["serve_fleet"] = {
        "engines": metrics,
        "replicas": n_replicas,
        "prefix_groups": n_prefixes,
        "fleet_affinity_vs_rr_hit_tokens": round(hit_ratio, 3),
        "fleet_affinity_vs_rr_tok_s": round(tok_ratio, 3),
        "token_match_rate": round(match_rate, 4),
    }


def serve_disagg(state: Dict) -> None:
    """Disaggregated prefill/decode pools (docs/serving.md §disaggregated
    serving) vs the colocated paged engine on the bursty phase-skewed
    stream (docs/perf.md §TTFT under burst): steady short-prompt decode
    traffic with synchronized long-prompt bursts, the ingress shape where
    colocated admission stalls every queued short request behind the
    burst's large-bucket prefills.

    In-process pools drain sequentially on the host, so the gated
    quantities are scheduling/shipping quality, not parallel speedup:

    - ``burst_ttft_p95_improvement``: colocated/disagg ratio of
      short-prompt TTFT p95 (>1 expected — ingest-first admission plus
      shortest-bucket-first cold ordering stop bursts from starving
      shorts);
    - ``hit_ttft_p95_improvement``: same ratio on an all-hits replay of a
      seen stream (decode-side TTFT must not regress when the radix tree
      already spans the prompt);
    - ``disagg_vs_colocated_tok_s``: shipping-overhead floor (<1 on one
      host — every cold admission pays an extra gather/ship/scatter
      dispatch triple), gated so the page-shipping path can't silently
      rot;
    - ``token_match_rate``: disagg must be BIT-IDENTICAL to colocated
      (prefill_admit writes what admit_cold would write and shipping is
      value-preserving), gated at the absolute 0.99 floor, expected 1.0.

    The hit-phase replay additionally hard-asserts the radix-spanning
    contract: prefix hits climb while ship_dispatches stays flat —
    a decode-side hit performs ZERO page transfers.

    Measured passes use FRESH streams (the radix tree persists between
    passes; replaying one stream would turn every cold admission into a
    hit and null the shipping path under test)."""
    import jax as _jax
    from repro.configs import get_config
    from repro.kernels import ops as kops
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.stream import bursty_requests, replay

    n_dev = _jax.device_count()
    if n_dev < 2:
        row("serve_disagg_skipped", 0.0,
            "needs a multi-device host platform (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 before jax init); "
            "gated keys omitted from this run")
        state.setdefault("skipped", set()).add("serve_disagg")
        return
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, _jax.random.PRNGKey(0))
    n_req, reps = 24, 3
    # 1:1 pools: P() over a wider pool replicates params and arena across
    # its fake host devices (every op runs on all of them, every ship
    # copies to all of them), which measures replication overhead instead
    # of the handoff.  Wider splits are the cost model's job
    # (plan_search's disagg axis); the bench measures the mechanism.
    p_pool = d_pool = 1
    rng = np.random.default_rng(0)
    long_cut = 180  # bursty_requests long_range floor; short/long ranges
    # never overlap, so burst membership is classifiable from len(prompt)

    def mk_stream():
        # short_range floor >= page_size so every prompt caches at least
        # one full page — the hit-phase replay must be hits, not colds
        return bursty_requests(rng, n_req, cfg.vocab_size,
                               short_range=(24, 40), burst_every=6,
                               burst_size=3, budgets=(4, 8), rate=600.0)

    # streams[0] warms compilation x2 (cold + hit admission paths);
    # streams[1] is a discarded fresh pass (steady-state admission batch
    # shapes); streams[2..reps+1] are the measured passes; streams[-1]
    # drives the hit phase (served cold once, then replayed — all hits)
    pass_streams = [mk_stream() for _ in range(reps + 3)]
    # deadline_s widened past ref-impl CPU prefill timescales for BOTH
    # arms: at the production default every queued request is overdue
    # within one CPU prefill and both schedulers collapse to the
    # overdue-FIFO guarantee — the quantity under test is the admission
    # *ordering* split (ingest-first + SJF chunk vs FIFO), not the
    # shared overdue fallback
    engine_kw = dict(max_batch=4, buckets=(64, 256), max_decode_len=16,
                     num_pages=256, deadline_s=60.0)
    setups = (("colocated", {}), ("disagg", {"disagg": (p_pool, d_pool)}))
    names = [n for n, _ in setups]
    metrics, streams, streams_hit = {}, {}, {}
    pass_tok = {n: [] for n in names}    # per-pass tok/s
    pass_short = {n: [] for n in names}  # per-pass short-TTFT p95 (ms)
    pass_hit = {n: [] for n in names}    # per-replay hit-TTFT p95 (ms)
    pass_wall = {n: [] for n in names}   # (wall_s, tokens) per pass
    with kops.pinned_impl("ref"):
        engines = {}
        for name, extra in setups:
            eng = ContinuousBatchingEngine(model, params, **engine_kw,
                                           **extra)
            replay(eng, pass_streams[0], warmup=False)  # compile, cold
            replay(eng, pass_streams[0], warmup=False)  # compile, hits
            replay(eng, pass_streams[1], warmup=False)  # fresh warm
            engines[name] = eng
            streams[name] = []
        pre0 = {n: e.stats["prefills"] for n, e in engines.items()}
        ship0 = {n: e.stats.get("ship_dispatches", 0)
                 for n, e in engines.items()}
        # measured passes run ARM-PAIRED on the same fresh stream: the
        # bench box is small and shared, so wall-clock drift between
        # passes dwarfs the arm difference — pairing the arms inside one
        # load window and gating the MEDIAN of per-pass ratios is what
        # makes the ratios reproducible
        for p in range(2, reps + 2):
            for name, eng in engines.items():
                done, wall, tok_s, ttft = replay(eng, pass_streams[p],
                                                 warmup=False)
                streams[name].append(
                    {r.rid: tuple(r.tokens_out) for r in done})
                sh = [t for r, t in zip(done, ttft)
                      if len(r.prompt) < long_cut]
                pass_tok[name].append(tok_s)
                pass_short[name].append(float(np.percentile(sh, 95)))
                pass_wall[name].append(
                    (wall, sum(len(r.tokens_out) for r in done)))
        prefills = {n: e.stats["prefills"] - pre0[n]
                    for n, e in engines.items()}
        ships = {n: e.stats.get("ship_dispatches", 0) - ship0[n]
                 for n, e in engines.items()}
        # hit phase: seed one fresh stream cold in both arms, then paired
        # all-hit replays — every prompt is now spanned by the radix
        # tree, so the disagg arm must admit via the decode pool alone
        # (zero page transfers); hits skip prefill, so the p95 is
        # dispatch-cadence-dominated and needs the replay pooling too
        for name, eng in engines.items():
            replay(eng, pass_streams[-1], warmup=False)
        hits0 = {n: e.stats["prefix_hits"] for n, e in engines.items()}
        ship_h0 = {n: e.stats.get("ship_dispatches", 0)
                   for n, e in engines.items()}
        for _ in range(3):
            for name, eng in engines.items():
                done_h, _, _, t_h = replay(eng, pass_streams[-1],
                                           warmup=False)
                pass_hit[name].append(float(np.percentile(t_h, 95)))
                streams_hit[name] = {r.rid: tuple(r.tokens_out)
                                     for r in done_h}
        for name, eng in engines.items():
            d_hits = eng.stats["prefix_hits"] - hits0[name]
            d_ship = eng.stats.get("ship_dispatches", 0) - ship_h0[name]
            assert d_hits > 0, \
                f"serve_disagg[{name}]: hit-phase replay produced no " \
                f"prefix hits — the phase is not measuring hits"
            if name == "disagg":
                assert d_ship == 0, \
                    f"serve_disagg: {d_ship} page-shipping dispatches " \
                    f"during the all-hits phase — a decode-side prefix " \
                    f"hit must perform ZERO transfers (docs/serving.md)"
                metrics[name] = {
                    "hit_phase_ship_dispatches": int(d_ship),
                    "hit_phase_prefix_hits": int(d_hits),
                    "ship_dispatches": int(ships[name]),
                    "shipped_pages": int(eng.stats["shipped_pages"]),
                    "shipped_bytes": int(eng.stats["shipped_bytes"]),
                }
        for name in names:
            tok_s = float(np.median(pass_tok[name]))
            wall, toks = sorted(pass_wall[name])[reps // 2]
            metrics.setdefault(name, {}).update(
                tok_s=round(tok_s, 2),
                prefills=int(prefills[name]),
                short_ttft_p95_ms=round(np.median(pass_short[name]), 2),
                hit_ttft_p95_ms=round(np.median(pass_hit[name]), 2))
            row(f"serve_disagg_{name}_per_token", wall / toks * 1e6,
                f"{tok_s:.1f}tok/s short_ttft_p95="
                f"{np.median(pass_short[name]):.1f}ms "
                f"prefills={prefills[name]}"
                + (f" ships={ships[name]}" if name == "disagg" else ""))
    tot = matched = 0
    for p in range(reps):
        for rid, ts in streams["colocated"][p].items():
            tot += len(ts)
            matched += sum(a == b
                           for a, b in zip(ts, streams["disagg"][p][rid]))
    for rid, ts in streams_hit["colocated"].items():
        tot += len(ts)
        matched += sum(a == b
                       for a, b in zip(ts, streams_hit["disagg"][rid]))
    match_rate = matched / max(tot, 1)
    med = lambda pairs: float(np.median(pairs))  # noqa: E731
    tok_ratio = med([d / c for d, c in zip(pass_tok["disagg"],
                                           pass_tok["colocated"])])
    burst_ratio = med([c / max(d, 1e-9)
                       for c, d in zip(pass_short["colocated"],
                                       pass_short["disagg"])])
    hit_ratio = med([c / max(d, 1e-9)
                     for c, d in zip(pass_hit["colocated"],
                                     pass_hit["disagg"])])
    row("serve_disagg_vs_colocated_tok_s", tok_ratio,
        f"{p_pool}:{d_pool} pools on {n_dev} host devices: shipping "
        "overhead floor (<1 expected — every cold admission pays the "
        "gather/ship/scatter triple; gated so the path can't rot)")
    row("serve_disagg_burst_ttft_p95_improvement", burst_ratio,
        "colocated/disagg short-prompt TTFT p95 (>1 expected — "
        "ingest-first admission + shortest-bucket-first cold ordering "
        "keep bursts from starving shorts; docs/perf.md §TTFT under "
        "burst)")
    row("serve_disagg_hit_ttft_p95_improvement", hit_ratio,
        "colocated/disagg TTFT p95 on the all-hits replay (decode-side "
        "admission must not regress when the radix tree spans the prompt)")
    row("serve_disagg_token_match_rate", match_rate,
        f"{matched}/{tot} disagg tokens identical to colocated across "
        "measured + hit passes (bit-identity floor 0.99, expected 1.0)")
    state.setdefault("bench_json", {})["serve_disagg"] = {
        "engines": metrics,
        "devices": n_dev,
        "disagg": [p_pool, d_pool],
        "disagg_vs_colocated_tok_s": round(tok_ratio, 3),
        "burst_ttft_p95_improvement": round(burst_ratio, 3),
        "hit_ttft_p95_improvement": round(hit_ratio, 3),
        "token_match_rate": round(match_rate, 4),
    }


PLAN_FAMILIES = ("smollm-135m", "ibert-base", "phi3-medium-14b",
                 "moonshot-v1-16b-a3b")


def _plans_dir() -> str:
    import os
    return os.path.join(os.path.dirname(os.path.abspath(__file__)), "plans")


def _plan_snapshot_path(arch: str) -> str:
    import os
    return os.path.join(_plans_dir(), arch.replace("-", "_") + ".json")


def _default_profile():
    """The traffic profile the committed plan snapshots are searched for:
    benchmarks/profiles/default.json when present (the file CI's
    plan-search job and `serve.py --plan auto --traffic` share), else the
    built-in TrafficProfile defaults (kept identical)."""
    import os
    from repro.core.plan_search import TrafficProfile
    p = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "profiles", "default.json")
    return TrafficProfile.from_json(p) if os.path.exists(p) \
        else TrafficProfile()


def plan_search_bench(state: Dict) -> None:
    """Cost-model plan auto-search over the CI config families
    (docs/serving.md §plan auto-search): searches each family against the
    default traffic profile and emits the chosen-plan snapshots that
    `--check-plans` diffs against benchmarks/plans/ (the CI snapshot
    gate) and `--write-plans` refreshes.  The search itself is pure
    arithmetic on jaxpr-traced counts — deterministic, so any drift is a
    code/profile change, never noise."""
    from repro.configs import get_config
    from repro.core.plan_search import search, to_snapshot

    profile = _default_profile()
    archs = state.get("plan_archs") or PLAN_FAMILIES
    snaps = {}
    for arch in archs:
        cfg = get_config(arch)
        t0 = time.perf_counter()
        res = search(cfg, profile)
        elapsed = time.perf_counter() - t0
        ch = res.chosen
        derived = (f"chosen={ch.key} pred={ch.tok_s:.0f}tok/s "
                   f"ttft={ch.ttft_ms:.2f}ms hbm={ch.hbm_frac:.2f}"
                   if ch else "chosen=NONE")
        row(f"plan_search_{arch.replace('-', '_')}", elapsed * 1e6,
            f"{derived} {res.n_feasible}/{len(res.scores)} feasible "
            f"frontier={len(res.frontier)}")
        snaps[arch] = to_snapshot(cfg, res)
    state["plan_snapshots"] = snaps
    state.setdefault("bench_json", {})["plan_search"] = {
        "profile": profile.to_dict(),
        "snapshots": snaps,
    }


def check_plans(snaps: Dict) -> int:
    """Snapshot gate: diff freshly searched plans against the committed
    benchmarks/plans/ files.  Structural drift (chosen candidate,
    frontier, profile, cost-model version) or a missing snapshot fails;
    predicted-number deltas are informational (plan_search.diff_snapshots
    owns the split).  0 = clean, 1 = drift."""
    import json
    import os
    from repro.core.plan_search import diff_snapshots
    failed = False
    for arch, snap in snaps.items():
        path = _plan_snapshot_path(arch)
        if not os.path.exists(path):
            print(f"PLAN SNAPSHOT MISSING {path} (family {arch})")
            failed = True
            continue
        with open(path) as f:
            committed = json.load(f)
        hard, info = diff_snapshots(committed, snap)
        for line in info:
            print(f"  plan {arch} (informational): {line}")
        if hard:
            print(f"PLAN SNAPSHOT DRIFT {arch} vs {path}:")
            for line in hard:
                print(f"  DRIFT {line}")
            failed = True
        else:
            print(f"plan snapshot OK: {arch}")
    if failed:
        print("plan snapshots drifted: if the new choices are intended, "
              "refresh with `python benchmarks/run.py plan_search "
              "--write-plans` (CI: the refresh-plans workflow_dispatch "
              "job) and commit benchmarks/plans/")
        return 1
    return 0


def write_plans(snaps: Dict) -> None:
    import json
    import os
    os.makedirs(_plans_dir(), exist_ok=True)
    for arch, snap in snaps.items():
        path = _plan_snapshot_path(arch)
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote plan snapshot {path}")


def _calibrate_engine(eng, reps: int = 3, ns=(1, 8)):
    """Two-point decode calibration for the predicted-vs-measured gate
    (docs/perf.md §cost model): time the engine's fused n-step dispatch
    at n=1 and n=8 on probe caches (the serving caches are untouched),
    then split marginal step cost from fixed dispatch overhead — the
    measured analogue of the paper's Table 1 T/I fit."""
    from repro.core.plan_search import DeviceCalibration

    ex = eng.executor
    pargs = ((eng.page_size, eng.kv.num_pages, eng.max_pages,
              eng.kv_dtype) if eng.paged else ())
    st = ex.fresh_state(ex.init_caches(eng.paged, *pargs), eng.paged)
    t = {}
    for n in ns:
        np.asarray(ex.decode(st, n, eng.paged))  # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            np.asarray(ex.decode(st, n, eng.paged))
            ts.append(time.perf_counter() - t0)
        t[n] = float(np.median(ts))
    # prefill probe: one batch-1 bucketed dispatch (the admission unit),
    # at the engine's largest bucket (conservative for shorter prompts)
    prompt = [[1] * (max(eng.buckets) - 1)]
    jax.block_until_ready(ex.prefill_prompts(prompt, 1, bucket_cache=True))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(
            ex.prefill_prompts(prompt, 1, bucket_cache=True))
        ts.append(time.perf_counter() - t0)
    return DeviceCalibration.from_two_point(
        t[ns[0]], ns[0], t[ns[1]], ns[1], t_prefill=float(np.median(ts)))


def _predicted_entry(calib, eng, done, measured_tok_s: float) -> Dict:
    """One engine's `_predicted` stamp: model prediction from the
    calibrated costs + the stream's declared shape, next to measured."""
    from repro.core.plan_search import predict_engine_tok_s

    toks = sum(len(r.tokens_out) for r in done)
    ptoks = sum(len(r.prompt) for r in done)
    pred = predict_engine_tok_s(
        calib, n_requests=len(done), total_tokens=toks,
        prompt_tokens=ptoks, max_batch=eng.max_batch,
        horizon=eng.decode_horizon)
    return {
        "predicted_tok_s": round(pred, 2),
        "measured_tok_s": round(measured_tok_s, 2),
        "ratio": round(pred / max(measured_tok_s, 1e-9), 4),
        "t_step_ms": round(calib.t_step_s * 1e3, 4),
        "t_dispatch_ms": round(calib.t_dispatch_s * 1e3, 4),
        "t_prefill_ms": round(calib.t_prefill_s * 1e3, 4),
    }


BENCHES = {
    "table1": table1_encoder_latency,
    "table2": table2_full_model_eq1,
    "table3": table3_padding_vs_nopadding,
    "table4": table4_throughput,
    "sec9": sec9_v5e_estimate,
    "table5": table5_accelerator_comparison,
    "fig15": fig15_cluster_resources,
    "gmi": gmi_collective_models,
    "kernels": bench_int8_kernels,
    "serve_cb": serve_cb,
    "serve_paged": serve_paged,
    "serve_quant": serve_quant,
    "serve_sharded": serve_sharded,
    "serve_throughput": serve_throughput,
    "serve_spec": serve_spec,
    "serve_fleet": serve_fleet,
    "serve_disagg": serve_disagg,
    "plan_search": plan_search_bench,
}

# benches whose state is produced by earlier benches in the full sweep
_ORDER = ["table1", "table2", "table3", "table4", "sec9", "table5",
          "fig15", "gmi", "kernels", "serve_cb", "serve_paged",
          "serve_quant", "serve_sharded", "serve_throughput", "serve_spec",
          "serve_fleet", "serve_disagg", "plan_search"]

# every gated section DECLARES the gate-owned metrics it emits (the leaf
# names _gate_walk owns).  --list derives its table from these
# declarations — not from the committed baseline — so a new gated section
# shows up the moment it exists and a stale baseline is loudly flagged
# instead of silently shipping the section ungated.
serve_cb.gate_keys = ("tok_s", "dispatches_per_token",
                      "fused_vs_single_step_tok_s",
                      "dispatches_per_token_drop")
serve_paged.gate_keys = ("tok_s", "dispatches_per_token",
                         "paged_vs_dense_tok_s",
                         "paged_vs_dense_concurrency")
serve_quant.gate_keys = ("tok_s", "dispatches_per_token",
                         "int8_vs_bf16_tok_s", "int8_vs_bf16_concurrency",
                         "token_match_rate")
serve_sharded.gate_keys = ("tok_s", "dispatches_per_token",
                           "sharded_vs_single_tok_s", "token_match_rate")
serve_throughput.gate_keys = ("tok_s", "dispatches_per_token",
                              "throughput_vs_exact_tok_s",
                              "token_match_rate")
serve_spec.gate_keys = ("tok_s", "dispatches_per_token",
                        "spec_vs_cb_tok_s", "token_match_rate")
serve_fleet.gate_keys = ("tok_s", "fleet_affinity_vs_rr_hit_tokens",
                         "fleet_affinity_vs_rr_tok_s", "token_match_rate")
serve_disagg.gate_keys = ("tok_s", "disagg_vs_colocated_tok_s",
                          "burst_ttft_p95_improvement",
                          "hit_ttft_p95_improvement", "token_match_rate")
_NEEDS = {"table2": ["table1"], "table3": ["table1"],
          "table4": ["table1", "table3"], "table5": ["sec9"]}

# perf-regression gate thresholds (--check-against): tok/s may regress up
# to 25% before failing (CI boxes are noisy); dispatches/token is
# scheduling-deterministic up to arrival-timing jitter, so it gets a
# tighter 10% band — any real fusion regression is far larger than that.
# Absolute tok/s is machine-relative (regenerate the baseline when the
# runner class changes); the speedup *ratios* below are gated too because
# they compare two engines measured on the same box in the same run and
# therefore transfer across hardware.
TOK_S_REGRESSION = 0.25
DISP_TOK_INCREASE = 0.10
RATIO_KEYS = ("paged_vs_dense_tok_s", "paged_vs_dense_concurrency",
              "fused_vs_single_step_tok_s", "dispatches_per_token_drop",
              "int8_vs_bf16_tok_s", "int8_vs_bf16_concurrency",
              "sharded_vs_single_tok_s", "throughput_vs_exact_tok_s",
              "spec_vs_cb_tok_s", "fleet_affinity_vs_rr_hit_tokens",
              "fleet_affinity_vs_rr_tok_s", "disagg_vs_colocated_tok_s",
              "burst_ttft_p95_improvement", "hit_ttft_p95_improvement")
# absolute floor: int8 greedy streams must match bf16 on >=99% of tokens —
# accuracy is not machine-relative, so no baseline-relative band applies
TOKEN_MATCH_FLOOR = 0.99
# per-section overrides: serve_throughput is explicitly NOT bit-exact
# (request-skewed schedule; docs/serving.md §exactness contract) and is
# gated at the contract's 0.98 band instead of the bit-identity floor
_MATCH_FLOORS = {"serve_throughput": 0.98}
_GATED_LEAVES = ("tok_s", "dispatches_per_token", "token_match_rate")


def _run_meta(state: Dict) -> Dict:
    """Provenance stamp for every BENCH_*.json: which jax, which devices,
    which meshes produced these numbers.  Absolute tok_s is meaningless
    without it — a baseline regenerated on a different runner class or
    device count LOOKS like a perf change otherwise.  Never gated: the
    gate and the perf.yml diff both pop `_run_meta` before comparing."""
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "mesh_shapes": state.get("meshes", {}),
    }


def _gate_walk(base, cur, path=""):
    """Compare a bench_json tree against a committed baseline; returns a
    list of violation strings (empty = gate passes).  Only the metrics the
    gate owns are compared — every `tok_s` (lower = regression), every
    `dispatches_per_token` (higher = regression), the speedup ratios and
    the absolute `token_match_rate` floor; other keys are context."""
    bad = []
    if isinstance(base, dict):
        for k, v in base.items():
            sub = cur.get(k) if isinstance(cur, dict) else None
            if sub is None and not isinstance(v, dict):
                if k in _GATED_LEAVES or k in RATIO_KEYS:
                    bad.append(f"{path}{k}: missing from current run")
                continue
            bad += _gate_walk(v, sub, f"{path}{k}.")
        return bad
    key = path.rstrip(".").rsplit(".", 1)[-1]
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        if key in _GATED_LEAVES or key in RATIO_KEYS:
            bad.append(f"{path.rstrip('.')}: non-numeric value {cur!r} "
                       f"in current run (baseline {base})")
        return bad
    if key == "tok_s" or key in RATIO_KEYS:
        floor = base * (1 - TOK_S_REGRESSION)
        if cur < floor:
            bad.append(f"{path.rstrip('.')}: {cur} < {floor:.2f} "
                       f"(baseline {base}, -{TOK_S_REGRESSION:.0%} floor)")
    elif key == "dispatches_per_token":
        ceil = base * (1 + DISP_TOK_INCREASE)
        if cur > ceil:
            bad.append(f"{path.rstrip('.')}: {cur} > {ceil:.4f} "
                       f"(baseline {base}, +{DISP_TOK_INCREASE:.0%} ceiling)")
    elif key == "token_match_rate":
        floor = _MATCH_FLOORS.get(path.split(".", 1)[0], TOKEN_MATCH_FLOOR)
        if cur < floor:
            bad.append(f"{path.rstrip('.')}: {cur} < {floor} "
                       f"(absolute accuracy floor; baseline {base})")
    return bad


def _gated_paths(tree, path=""):
    """Dotted paths of every gate-owned metric in a bench_json tree."""
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            if isinstance(v, dict):
                out += _gated_paths(v, f"{path}{k}.")
            elif k in _GATED_LEAVES or k in RATIO_KEYS:
                out.append(f"{path}{k}")
    return out


def _pop_predicted(tree: Dict) -> Dict:
    """Strip the per-section `_predicted` stamps (popped like `_run_meta`
    before baseline comparison, so baselines committed before the
    prediction gate existed stay valid and never grow the key)."""
    return {k: ({kk: vv for kk, vv in v.items() if kk != "_predicted"}
                if isinstance(v, dict) else v)
            for k, v in tree.items()}


def check_prediction_band(bench_json: Dict) -> List[str]:
    """The predicted-vs-measured accuracy gate (docs/perf.md §cost
    model): every `_predicted` entry a serve bench stamped must have its
    predicted/measured tok/s ratio inside the band it declared.  Returns
    violation strings (empty = within band)."""
    bad = []
    for sec, body in sorted(bench_json.items()):
        if not isinstance(body, dict):
            continue
        pred = body.get("_predicted")
        if not isinstance(pred, dict):
            continue
        lo, hi = pred.get("band", (0.0, float("inf")))
        for name, entry in sorted(pred.items()):
            if not isinstance(entry, dict) or "ratio" not in entry:
                continue
            r = entry["ratio"]
            if not lo <= r <= hi:
                bad.append(
                    f"{sec}.{name}: predicted/measured tok/s ratio {r} "
                    f"outside [{lo}, {hi}] (predicted "
                    f"{entry.get('predicted_tok_s')}, measured "
                    f"{entry.get('measured_tok_s')}) — the cost model "
                    "has drifted from the device; recalibrate or fix "
                    "core/plan_search before trusting its plans")
    return bad


def check_against(baseline_path: str, bench_json: Dict,
                  ran=None) -> int:
    """Exit-code-style perf gate: 0 = within thresholds, 1 = regression.

    Fails with an explicit message — never a KeyError — when the baseline
    and the current run disagree on *which* gated metrics exist: a metric
    the baseline expects but the run didn't produce is a regression, and a
    gated metric the run produced but the baseline has never seen (e.g.
    the first run after adding a benchmark axis) means the committed
    baseline must be refreshed before the gate can vouch for it.

    `ran` (bench names this invocation executed) scopes the comparison to
    the baseline's matching top-level sections: the PR perf-smoke job runs
    the serving benches and the multi-device job runs `serve_sharded`
    against the SAME committed baseline — each gate vouches only for the
    sections its own run produced, while "the bench ran but a gated
    metric vanished" still fails inside a section.
    """
    import json
    with open(baseline_path) as f:
        base = json.load(f)
    base.pop("rows", None)
    base.pop("_meta", None)
    base.pop("_run_meta", None)
    base = _pop_predicted(base)
    # enforce the predicted-vs-measured band BEFORE stripping the stamps:
    # the band is self-declared per section, never baseline-relative
    pred_bad = check_prediction_band(bench_json)
    bench_json = _pop_predicted(
        {k: v for k, v in bench_json.items() if k != "_run_meta"})
    if ran is not None:
        base = {k: v for k, v in base.items() if k in ran}
        bench_json = {k: v for k, v in bench_json.items() if k in ran}
    # report EVERYTHING wrong in one run: all gated metrics the baseline
    # has never seen AND all threshold violations against the metrics it
    # does have — a first-run-after-new-section failure must not mask a
    # real regression in the established sections (and vice versa)
    missing = sorted(set(_gated_paths(bench_json)) - set(_gated_paths(base)))
    bad = _gate_walk(base, bench_json)
    if missing:
        print(f"PERF GATE UNUSABLE: {baseline_path} has no entry for "
              f"gated metric(s) produced by this run:")
        for m in missing:
            print(f"  MISSING BASELINE KEY {m}")
        print("refresh the committed baseline (CI: the baseline-refresh "
              "workflow_dispatch job; locally: `python benchmarks/run.py "
              "serve_cb serve_spec --shared-prefix --kv-dtype int8 "
              "--write-baseline benchmarks/baseline.json` on a quiet box) "
              "and commit it")
    if bad:
        print(f"PERF GATE FAILED vs {baseline_path}:")
        for b in bad:
            print(f"  REGRESSION {b}")
    if pred_bad:
        print("PREDICTION BAND FAILED:")
        for b in pred_bad:
            print(f"  PREDICTION {b}")
    if missing or bad or pred_bad:
        return 1
    print(f"perf gate OK vs {baseline_path}")
    return 0


def main(argv=None) -> None:
    import json
    import sys
    args = list(argv if argv is not None else sys.argv[1:])

    def _path_flag(flag):
        if flag not in args:
            return None
        i = args.index(flag)
        try:
            p = args[i + 1]
        except IndexError:
            raise SystemExit(f"{flag} requires a value")
        del args[i:i + 2]
        return p

    if "--list" in args:  # enumerate benches + their DECLARED gate keys
        # keys come from each section's own `gate_keys` declaration, not
        # from the committed baseline — a freshly added gated section is
        # listed (and flagged) even before the baseline has been
        # refreshed, so it can never silently ship ungated
        import os
        base = {}
        bp = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "baseline.json")
        if os.path.exists(bp):
            with open(bp) as f:
                base = json.load(f)
            base.pop("_meta", None)
            base.pop("rows", None)
        print(f"{'bench':<14} declared gate keys (baseline: {bp})")
        stale = []
        for name in _ORDER:
            keys = getattr(BENCHES[name], "gate_keys", ())
            if not keys:
                print(f"{name:<14} (not gated)")
                continue
            covered = set(p.rsplit(".", 1)[-1]
                          for p in _gated_paths(base.get(name, {})))
            missing = [k for k in keys if k not in covered]
            mark = (f"  [NOT IN BASELINE: {', '.join(missing)}]"
                    if missing else "")
            if missing:
                stale.append(name)
            print(f"{name:<14} " + ", ".join(keys) + mark)
        if stale:
            print(f"\nWARNING: baseline lacks gated keys for "
                  f"{', '.join(stale)} — refresh it before merging "
                  "(--write-baseline merges per-section)")
        # plan-snapshot staleness (the other committed trust artifact):
        # structural check only — missing file, cost-model version skew,
        # or a profile that no longer matches the default; full drift
        # needs the search itself (`plan_search --check-plans`)
        from repro.core.plan_search import COST_MODEL_VERSION
        profile = _default_profile().to_dict()
        print(f"\n{'plan family':<22} snapshot ({_plans_dir()})")
        plan_stale = []
        for arch in PLAN_FAMILIES:
            path = _plan_snapshot_path(arch)
            if not os.path.exists(path):
                status = "MISSING (run plan_search --write-plans)"
            else:
                with open(path) as f:
                    snap = json.load(f)
                if snap.get("cost_model_version") != COST_MODEL_VERSION:
                    status = (f"STALE: cost_model_version "
                              f"{snap.get('cost_model_version')} != "
                              f"{COST_MODEL_VERSION}")
                elif snap.get("profile") != profile:
                    status = "STALE: profile differs from default profile"
                else:
                    ch = (snap.get("chosen") or {}).get("key", "NONE")
                    status = f"ok  chosen={ch}"
            if not status.startswith("ok"):
                plan_stale.append(arch)
            print(f"{arch:<22} {status}")
        if plan_stale:
            print(f"\nWARNING: plan snapshot missing/stale for "
                  f"{', '.join(plan_stale)} — refresh with `python "
                  "benchmarks/run.py plan_search --write-plans` (choice "
                  "drift itself is gated by plan_search --check-plans)")
        return

    json_path = _path_flag("--json")  # machine-readable perf trajectory
    check_path = _path_flag("--check-against")  # perf-regression gate
    write_baseline = _path_flag("--write-baseline")
    plan_archs = _path_flag("--plan-archs")  # scope plan_search families
    check_plans_flag = "--check-plans" in args  # plan snapshot gate
    if check_plans_flag:
        args.remove("--check-plans")
    write_plans_flag = "--write-plans" in args  # plan snapshot refresh
    if write_plans_flag:
        args.remove("--write-plans")
    kv_dtype = _path_flag("--kv-dtype")  # int8: add the quantized workload
    if kv_dtype not in (None, "bf16", "int8"):
        raise SystemExit(f"--kv-dtype must be bf16 or int8, got {kv_dtype}")
    shared_prefix = "--shared-prefix" in args
    if shared_prefix:  # serve_cb --shared-prefix: add the paged workload
        args.remove("--shared-prefix")
    names = args or list(_ORDER)
    if shared_prefix and "serve_paged" not in names:
        names.append("serve_paged")
    if kv_dtype == "int8" and "serve_quant" not in names:
        names.append("serve_quant")
    if (check_plans_flag or write_plans_flag) and "plan_search" not in names:
        names.append("plan_search")
    unknown = [n for n in names if n not in BENCHES]
    if unknown:  # fail before running anything — compiles cost minutes
        raise SystemExit(
            f"unknown benchmark(s) {unknown}; choose from {sorted(BENCHES)}")
    state: Dict = {}
    if plan_archs:
        # CI matrix family names use underscores; arch registry uses dashes
        requested = [a.strip().replace("_", "-")
                     for a in plan_archs.split(",") if a.strip()]
        from repro.configs import list_archs
        bad_archs = [a for a in requested if a not in list_archs()]
        if bad_archs:
            raise SystemExit(f"--plan-archs: unknown arch(es) {bad_archs}")
        state["plan_archs"] = tuple(requested)
    ran = set()
    for name in names:
        for dep in _NEEDS.get(name, []):
            if dep not in ran:
                BENCHES[dep](state)
                ran.add(dep)
        if name not in ran:
            BENCHES[name](state)
            ran.add(name)
    print(f"\n{len(ROWS)} benchmark rows")
    bench_json = state.get("bench_json", {})
    if json_path is not None:
        payload = dict(bench_json, rows=ROWS, _run_meta=_run_meta(state))
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    if write_baseline is not None:
        import os
        # MERGE into an existing baseline: only the sections this run
        # produced are replaced, so the single-device serving refresh and
        # the 8-device serve_sharded refresh compose into one file
        payload: Dict = {}
        if os.path.exists(write_baseline):
            with open(write_baseline) as f:
                payload = json.load(f)
        # `_predicted` stamps are machine-relative model diagnostics —
        # they never enter the committed baseline (the band is enforced
        # per-run, not baseline-relative)
        payload.update(_pop_predicted(bench_json))
        payload["_meta"] = {
            "note": "perf-gate baseline; regenerate ON A QUIET BOX OF THE "
                    "CI RUNNER CLASS with `python benchmarks/run.py "
                    "serve_cb serve_spec --shared-prefix --kv-dtype int8 "
                    "--write-baseline benchmarks/baseline.json` plus "
                    "`XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                    "python benchmarks/run.py serve_sharded "
                    "serve_throughput "
                    "--write-baseline benchmarks/baseline.json` (writes "
                    "MERGE per-section) — or one click via the "
                    "baseline-refresh workflow_dispatch job (absolute "
                    "tok_s is machine-relative; the speedup ratios and "
                    "token_match_rate transfer)",
            "gate": {"tok_s_regression": TOK_S_REGRESSION,
                     "dispatches_per_token_increase": DISP_TOK_INCREASE,
                     "token_match_floor": TOKEN_MATCH_FLOOR,
                     "match_floor_overrides": dict(_MATCH_FLOORS),
                     "ratio_keys": list(RATIO_KEYS)}}
        with open(write_baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote baseline {write_baseline}")
    rc = 0
    if write_plans_flag:
        write_plans(state.get("plan_snapshots", {}))
    if check_plans_flag:
        rc = max(rc, check_plans(state.get("plan_snapshots", {})))
    if check_path is not None:
        rc = max(rc, check_against(check_path, bench_json,
                                   ran=ran - state.get("skipped", set())))
    if rc:
        sys.exit(rc)


if __name__ == "__main__":
    main()
