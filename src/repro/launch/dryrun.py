import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any other import: jax locks the
#   device count at first init, and the dry-run needs 512 host platform
#   placeholder devices to build the production meshes.

# Multi-pod dry-run (brief: MULTI-POD DRY-RUN + ROOFLINE ANALYSIS).
#
# For every (architecture x shape-cell x mesh): build the Cluster-Builder
# sharding plan, lower + compile the appropriate step (train_step for
# train_4k, prefill/serve_step for the inference cells) against
# ShapeDtypeStruct inputs (no allocation), then record
# memory_analysis / cost_analysis / HLO-collective bytes into a JSON file
# that EXPERIMENTS.md and the roofline table are generated from.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --cell all \
#       --mesh both --out experiments/dryrun
#   (incremental: existing JSONs are skipped unless --force)

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_CELLS, get_config, list_archs
from repro.core.cluster_builder import build_plan
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_params, make_model
from repro.optim.optimizer import cosine_schedule, make_optimizer
from repro.roofline.analysis import analyze, model_flops, suggest
from repro.roofline.hlo import collective_bytes
from repro.roofline.jaxpr_cost import count_costs


def input_specs(cfg, cell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = cell.global_batch, cell.seq_len
    sds = jax.ShapeDtypeStruct
    if cell.kind == "train":
        specs: Dict[str, Any] = {"labels": sds((b, s), jnp.int32)}
        if cfg.frontend != "none":
            specs["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = sds((b, s), jnp.int32)
        return specs
    if cell.kind == "prefill":
        if cfg.frontend != "none":
            return {"embeds": sds((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": sds((b, s), jnp.int32)}
    # decode: one new token against an s-deep cache
    return {"token": sds((b,), jnp.int32)}


def _ns(mesh, spec):
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, spec)


def _mem_analysis(compiled) -> Dict[str, float]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes",
                  "host_temp_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = float(v)
        out["repr"] = str(ma)[:2000]
    except Exception as e:  # noqa: BLE001
        out["error"] = repr(e)
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        return {str(k): float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        return {"error_repr": 0.0, "_err": repr(e)}  # type: ignore


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             verbose: bool = True, variant: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name,
        "kind": cell.kind, "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "variant": variant,
    }
    if cell_name in cfg.skip_cells:
        rec["status"] = "SKIP"
        rec["skip_reason"] = cfg.skip_reason
        return rec
    int8serve = variant == "int8serve" and cell.kind != "train"

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = make_model(cfg)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if int8serve:
        from repro.models.quantized import quantize_params_for_serving

        params_shape = jax.eval_shape(
            lambda k: quantize_params_for_serving(init_params(cfg, k)),
            key_sds)
    else:
        params_shape = jax.eval_shape(lambda k: init_params(cfg, k), key_sds)
    b, s = cell.global_batch, cell.seq_len

    caches_shape = None
    if cell.kind == "decode":
        caches_shape = jax.eval_shape(lambda: model.init_cache(b, s))
    plan = build_plan(cfg, mesh, params_shape, caches_shape, batch=b,
                      mode="train" if cell.kind == "train" else "serve")
    param_sh = jax.tree.map(lambda sp: _ns(mesh, sp), plan.param_specs)

    ins = input_specs(cfg, cell)
    data_sh = {k: _ns(mesh, plan.data_spec(len(v.shape), v.shape[0]))
               for k, v in ins.items()}

    if cell.kind == "train":
        from repro.launch.train import (
            default_micro_batches, make_train_step, opt_state_specs,
            pick_optimizer,
        )
        opt_name = pick_optimizer(cfg)
        rec["optimizer"] = opt_name
        opt_init, opt_update = make_optimizer(
            opt_name, cosine_schedule(3e-4, 100, 10000))
        opt_shape = jax.eval_shape(opt_init, params_shape)
        opt_specs = opt_state_specs(opt_shape, plan.param_specs, mesh)
        opt_sh = jax.tree.map(lambda sp: _ns(mesh, sp), opt_specs)
        from jax.sharding import PartitionSpec as P
        repl = _ns(mesh, P())
        dp_n = 1
        for a in plan.axes.dp:
            dp_n *= mesh.shape[a]
        n_micro = default_micro_batches(cfg, b, s, dp_n)
        rec["micro_batches"] = n_micro
        step = make_train_step(model, opt_update, n_micro=n_micro,
                               grad_shardings=param_sh)
        jitted = jax.jit(
            step, in_shardings=(param_sh, opt_sh, data_sh),
            out_shardings=(param_sh, opt_sh,
                           {"loss": repl, "grad_norm": repl}),
            donate_argnums=(0, 1))
        args = (params_shape, opt_shape, ins)
        raw_fn = step
    elif cell.kind == "prefill":
        cache_init_shape = jax.eval_shape(lambda: model.init_cache(b, s))
        cache_plan = build_plan(cfg, mesh, None, cache_init_shape, batch=b,
                                mode="serve")
        cache_sh = jax.tree.map(lambda sp: _ns(mesh, sp),
                                cache_plan.cache_specs)
        cache_sh["pos"] = _ns(mesh, cache_plan.data_spec(1, b))

        def prefill_step(params, data):
            caches = model.init_cache(b, s)
            logits, caches = model.prefill(params, caches, **data)
            return logits, caches

        jitted = jax.jit(prefill_step, in_shardings=(param_sh, data_sh),
                         out_shardings=(None, cache_sh))
        args = (params_shape, ins)
        raw_fn = prefill_step
    else:  # decode
        cache_sh = jax.tree.map(lambda sp: _ns(mesh, sp), plan.cache_specs)
        cache_sh["pos"] = _ns(mesh, plan.data_spec(1, b))

        def serve_step(params, caches, data):
            return model.decode_step(params, caches, data["token"])

        jitted = jax.jit(serve_step,
                         in_shardings=(param_sh, cache_sh, data_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
        args = (params_shape, caches_shape, ins)
        raw_fn = serve_step

    from repro.models.shard_hints import hints
    # int8 FSDP weight gathers for MoE training (§Perf B2): the optimized
    # configuration; the bf16 baseline is recorded in EXPERIMENTS.md §Perf
    int8_gather = cell.kind == "train" and cfg.n_experts > 0
    rec["int8_fsdp_gather"] = int8_gather
    with mesh, hints(mesh, dp_axes=plan.axes.dp, tp_axis=plan.axes.tp,
                     int8_gather=int8_gather):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec["hlo_bytes_len"] = len(hlo)
    del hlo
    # deterministic loop-weighted global counts (see roofline/jaxpr_cost.py)
    jcost = count_costs(raw_fn, *args)
    rec["jaxpr_cost_global"] = jcost

    chips = 512 if multi_pod else 256
    mf = model_flops(cfg, cell)
    terms = analyze(
        flops_per_device=jcost["flops"] / chips,
        bytes_per_device=jcost["bytes"] / chips,
        coll_bytes_per_device=coll.get("total", 0.0),
        chips=chips, model_flops_total=mf,
        int8=int8serve,  # int8 serving runs the GEMMs at 2x MXU peak
    )
    rec.update({
        "status": "OK",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collectives": coll,
        "roofline": terms.as_dict(),
        "suggestion": suggest(terms),
    })
    if verbose:
        print(f"[{mesh_name}] {arch} x {cell_name}: "
              f"compile {t_compile:.1f}s, dominant={terms.dominant}, "
              f"terms(c/m/coll)=({terms.compute_s:.2e}/{terms.memory_s:.2e}/"
              f"{terms.collective_s:.2e})s frac={terms.roofline_fraction:.3f}")
        print(mem.get("repr", "")[:400])
        for k, v in sorted(cost.items()):
            if isinstance(v, float) and v:
                print(f"  cost[{k}] = {v:.4g}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="'' (baseline) or 'int8serve' (W8A8 serving)")
    args = ap.parse_args(argv)

    archs = ([a for a in list_archs() if a != "ibert-base"]
             if args.arch == "all" else args.arch.split(","))
    cells = (list(SHAPE_CELLS) if args.cell == "all"
             else args.cell.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in meshes:
        mname = "multi" if multi else "single"
        for arch in archs:
            for cell in cells:
                suffix = f"__{args.variant}" if args.variant else ""
                fp = os.path.join(args.out,
                                  f"{mname}__{arch}__{cell}{suffix}.json")
                if os.path.exists(fp) and not args.force:
                    print(f"skip existing {fp}")
                    continue
                try:
                    rec = run_cell(arch, cell, multi, variant=args.variant)
                except Exception:  # noqa: BLE001
                    rec = {"arch": arch, "cell": cell, "mesh": mname,
                           "status": "FAIL",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append((mname, arch, cell))
                    print(f"FAIL {mname} {arch} {cell}")
                    print(rec["traceback"][-1500:])
                with open(fp, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall requested dry-run cells OK")


if __name__ == "__main__":
    main()
