"""Training driver: step factory + fault-tolerant CLI loop.

`make_train_step` is shared by the dry-run (lower/compile only) and the real
CPU-scale training example: one jit'd SPMD program computing
loss -> grad -> clip -> optimizer update, params/opt-state donated, sharded
per the Cluster Builder plan.  Gradient cross-pod reduction is implicit in
SPMD data parallelism; the GMI/compressed variants are exercised separately
(core/gmi.py, optim/compression.py) and compared in §Perf.

The CLI loop adds the production substrate: deterministic data pipeline,
async checkpointing, failure injection + recovery, straggler monitoring.

Usage (CPU example, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 60 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import functools
import logging
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.cluster_builder import build_plan
from repro.data.pipeline import TokenPipeline, shard_batch
from repro.models.transformer import Model, init_params, make_model
from repro.optim.optimizer import (
    clip_by_global_norm, cosine_schedule, make_optimizer,
)
from repro.runtime import FailureInjector, StragglerMonitor, run_with_recovery

log = logging.getLogger("repro.train")


def pick_optimizer(cfg) -> str:
    """adamw8 (int8 moments) for models whose f32 Adam state would not fit
    v5e HBM under full FSDP (DESIGN.md §2); f32 AdamW otherwise."""
    return "adamw8" if cfg.param_count() > 50e9 else "adamw"


def make_train_step(model: Model, opt_update, max_grad_norm: float = 1.0,
                    n_micro: int = 1, grad_shardings: Any = None):
    """One jit'd SPMD step; n_micro > 1 scans over gradient-accumulation
    microbatches so per-device live activations stay within HBM (the
    production memory lever for the 33B/400B train cells — DESIGN.md §3).

    grad_shardings (optional pytree of NamedSharding mirroring params) pins
    the f32 accumulator to the parameter sharding — without it XLA is free
    to replicate the accumulator (observed: 64GB/device expert-grad buffers
    on the 400B MoE)."""

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g))
                return (loss_acc + loss, g_acc), None

            g0 = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), g0), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt_update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm.astype(jnp.float32)}
        return params, opt_state, metrics

    return train_step


def default_micro_batches(cfg, global_batch: int, seq_len: int, dp: int,
                          act_budget_bytes: float = 0) -> int:
    """Smallest microbatch count (dividing the per-replica batch) that keeps
    remat-saved per-layer activations under the budget.

    MoE archs get a larger activation budget: every microbatch re-gathers
    the FSDP'd expert weights, so fewer/larger microbatches trade HBM for
    collective bytes (§Perf B1: 4x fewer expert gathers on the 400B)."""
    if not act_budget_bytes:
        act_budget_bytes = 12e9 if cfg.n_experts else 4e9
    b_loc = max(global_batch // dp, 1)
    per_row = seq_len * cfg.d_model * 2 * max(cfg.n_layers, 1)
    mb_loc = max(1, int(act_budget_bytes // max(per_row, 1)))
    n = max(1, -(-b_loc // mb_loc))
    while b_loc % n:
        n += 1
    return min(n, b_loc)


def jit_train_step(model: Model, opt_update, plan, opt_specs) -> Any:
    """jit with Cluster-Builder shardings; donates params+opt state."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def ns(spec):
        return NamedSharding(plan.mesh, spec)

    param_sh = jax.tree.map(ns, plan.param_specs)
    opt_sh = jax.tree.map(ns, opt_specs)
    repl = NamedSharding(plan.mesh, P())
    step = make_train_step(model, opt_update)
    return jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, None),
        out_shardings=(param_sh, opt_sh, {"loss": repl, "grad_norm": repl}),
        donate_argnums=(0, 1),
    )


def opt_state_specs(opt_state_shape, param_specs, mesh=None) -> Any:
    """Optimizer-state PartitionSpecs.

    f32 moments mirror their parameter (ZeRO: state lives with the param
    shard).  Block-quantized int8 moments are flat (nblk, BLOCK) arrays:
    their block dim is sharded across the WHOLE mesh (every chip owns a
    contiguous stripe — fully sharded optimizer state, the point of
    adamw8); scalars replicate."""
    from jax.sharding import PartitionSpec as P

    def blk_spec(sub, spec_sub):
        # param-shaped int8 moment: q shards exactly like the param; the
        # per-block scale drops the last (blocked) axis assignment
        if not hasattr(spec_sub, "__len__") or len(spec_sub) != sub["q"].ndim:
            return {"q": P(), "s": P()}
        qspec = spec_sub
        sspec = P(*(tuple(spec_sub[:-1]) + (None,)))
        return {"q": qspec, "s": sspec}

    def go(sub, spec_sub):
        if isinstance(sub, dict) and "q" in sub and "s" in sub:
            return blk_spec(sub, spec_sub)
        if isinstance(sub, dict):
            return {k: go(v, spec_sub.get(k) if isinstance(spec_sub, dict)
                          else spec_sub) for k, v in sub.items()}
        if spec_sub is None or not hasattr(sub, "shape") or sub.ndim == 0:
            return P()
        return spec_sub if sub.ndim == len(spec_sub) else P()

    out = {}
    for key, sub in opt_state_shape.items():
        if key in ("m", "v"):
            out[key] = go(sub, param_specs)
        else:
            out[key] = jax.tree.map(lambda _: P(), sub)
    return out


# ---------------------------------------------------------------------------
# CLI loop
# ---------------------------------------------------------------------------


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--pack", action="store_true",
                    help="no-padding packed sequences (paper §7.1)")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)
    opt_name = args.optimizer or pick_optimizer(cfg)
    lr_fn = cosine_schedule(args.lr, warmup=max(args.steps // 20, 5),
                            total=args.steps)
    opt_init, opt_update = make_optimizer(opt_name, lr_fn)
    step_fn = jax.jit(make_train_step(model, opt_update),
                      donate_argnums=(0, 1))

    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq,
                         seed=args.seed, pack=args.pack)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    injector = FailureInjector(
        {args.inject_failure_at: "node_loss"}
        if args.inject_failure_at >= 0 else {})
    monitor = StragglerMonitor()
    losses: list = []

    def make_state():
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        return {"params": params, "opt": opt_init(params)}

    def train_steps(state, start, stop):
        params, opt = state["params"], state["opt"]
        for step in range(start, stop):
            injector.check(step)
            batch = {k: jnp.asarray(v)
                     for k, v in pipe.next_batch().items()}
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(params, opt, batch)
            monitor.observe(step, time.perf_counter() - t0)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 10 == 0:
                log.info("step %d loss %.4f", step, loss)
        return {"params": params, "opt": opt}

    def save(step, state):
        ckpt.save(step, {"params": state["params"]})

    def restore():
        ckpt.wait()  # an in-flight async save must land before we pick
        latest = ckpt.latest_step()
        if latest is None:
            return None
        state = make_state()
        step, tree = ckpt.restore(latest,
                                  template={"params": state["params"]})
        return step, {"params": tree["params"], "opt": state["opt"]}

    state, report = run_with_recovery(
        make_state, train_steps, save, restore,
        total_steps=args.steps, checkpoint_every=args.ckpt_every)
    ckpt.wait()
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    log.info("loss %.4f -> %.4f, restarts=%d", first, last, report.restarts)
    print(f"train: arch={cfg.name} opt={opt_name} steps={args.steps} "
          f"loss {first:.4f} -> {last:.4f} restarts={report.restarts} "
          f"stragglers={len(monitor.events)}")
    return {"losses": losses, "report": report, "state": state}


if __name__ == "__main__":
    logging.basicConfig(level=logging.INFO)
    main()
