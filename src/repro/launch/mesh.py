"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the dry-run's
XLA_FLAGS=--xla_force_host_platform_device_count dance and for tests that
build small meshes.

Mesh shapes (TPU v5e target):
  single-pod: (16, 16)    axes (data, model)          = 256 chips
  multi-pod:  (2, 16, 16) axes (pod, data, model)     = 512 chips

`pod` is the inter-cluster axis in the paper's clusters-of-clusters sense
(§4): data-parallel by default, or the pipeline/cluster axis when the
Cluster Builder requests stage parallelism.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

try:  # jax >= 0.5 explicit-sharding API; absent on older jaxlibs
    from jax.sharding import AxisType
except (ImportError, AttributeError):  # deprecation shims raise AttributeError
    AxisType = None


def _axis_types_kw(n: int) -> dict:
    return {} if AxisType is None else {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(shape)))


def make_abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-free mesh for spec-only planning (tests, plan inspection)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # older jax: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def required_devices(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
