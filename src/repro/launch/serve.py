"""Serving driver CLI: bring up the continuous-batching engine for any
--arch and serve a Poisson request stream (the paper's kind of deployment:
a line-rate ingress feeding a spatial pipeline that never waits for a full
batch, §8.2).

Requests are submitted with exponential inter-arrival gaps and admitted
into freed KV-cache slots between decode steps; weights and the serving
cache are placed under the Cluster-Builder plan:

  --plan serve           kv-head-sharded paged serving over a
                         (data, model) mesh — bit-identical to
                         single-device (docs/serving.md §sharded serving)
  --plan serve_pipeline  layer stack sharded over a `stage` mesh axis,
                         decode micro-steps streamed with
                         collective_permute (the paper's 6-FPGA encoder
                         pipeline)
  --plan none            single-device (debug)

`--no-exact` switches either serve plan to throughput mode: psum-form TP
(serve) or the request-skewed pipeline schedule with stage-local KV
arenas (serve_pipeline) — faster, token streams gated by a match-rate
band instead of bitwise equality (docs/serving.md §exactness contract).

`--dryrun` prints the chosen plan's per-leaf shardings (params + serving
cache) and exits, so a deploy is inspectable before anything runs:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --plan serve --mesh 1,8 --dryrun
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.cluster_builder import build_plan
from repro.launch.mesh import make_mesh
from repro.models.transformer import init_params, make_model
from repro.runtime.stragglers import StragglerMonitor
from repro.serving.engine import ContinuousBatchingEngine, WaveEngine
from repro.serving.kv_manager import paged_eligible
from repro.serving.stream import (bursty_requests, poisson_requests,
                                  shared_prefix_requests)


def _parse_mesh(spec: str, plan_mode: str):
    """--mesh "1,8" -> (data, model) mesh; --mesh "8" under serve_pipeline
    -> (stage,) mesh.  Default: all visible devices on the plan's TP/stage
    axis."""
    n_dev = jax.device_count()
    if plan_mode == "serve_pipeline":
        shape = tuple(int(x) for x in spec.split(",")) if spec else (n_dev,)
        if len(shape) != 1:
            raise SystemExit("serve: serve_pipeline takes a 1-axis --mesh "
                             "(the stage axis), e.g. --mesh 8")
        return make_mesh(shape, ("stage",))
    shape = tuple(int(x) for x in spec.split(",")) if spec else (1, n_dev)
    if len(shape) != 2:
        raise SystemExit("serve: --plan serve takes a 2-axis --mesh "
                         "(data, model), e.g. --mesh 1,8")
    return make_mesh(shape, ("data", "model"))


def _fleet_plans(cfg, args):
    """Per-replica plans over disjoint device groups: --mesh is the shape
    of ONE replica's mesh (default: an even split of the host), and the
    fleet needs replicas x width devices (serving/replica.py raises
    otherwise)."""
    from repro.serving.replica import make_group_mesh, replica_device_groups
    n = args.replicas
    per = max(jax.device_count() // n, 1)
    if args.plan == "serve_pipeline":
        shape = (tuple(int(x) for x in args.mesh.split(","))
                 if args.mesh else (per,))
        if len(shape) != 1:
            raise SystemExit("serve: serve_pipeline takes a 1-axis --mesh "
                             "(the per-replica stage axis), e.g. --mesh 4")
        axes = ("stage",)
    else:
        shape = (tuple(int(x) for x in args.mesh.split(","))
                 if args.mesh else (1, per))
        if len(shape) != 2:
            raise SystemExit("serve: --plan serve takes a 2-axis --mesh "
                             "(data, model) per replica, e.g. --mesh 1,2")
        axes = ("data", "model")
    width = 1
    for s in shape:
        width *= s
    groups = replica_device_groups(n, width)
    return [build_plan(cfg, make_group_mesh(g, shape, axes),
                       mode=args.plan, exact=args.exact) for g in groups]


# projections that *reduce* over a contracted dim: replicated + gather-form
# under exact serving, column-sharded + psum-form under --no-exact
_REDUCTION_LEAVES = ("wo", "shared_wo", "glu_wo", "down", "w_out")


def _leaf_exactness(plan, path) -> str:
    """Exactness mode of one plan leaf, for --dryrun (docs/serving.md
    §exactness contract)."""
    name = path[-1] if path else ""
    if name in ("q", "scale") and len(path) > 1:  # quantized leaf pair
        name = path[-2]
    if plan.mode == "serve" and name in _REDUCTION_LEAVES:
        return "gather(exact)" if plan.exact else "psum(throughput)"
    if plan.mode == "serve_pipeline":
        return "drained(exact)" if plan.exact else "skewed(throughput)"
    return "exact"


def _print_shardings(title: str, specs, shapes, plan=None) -> None:
    print(f"-- {title} " + "-" * max(1, 60 - len(title)))

    def walk(sp, sh, path=()):
        if isinstance(sp, dict):
            for k in sorted(sp):
                walk(sp[k], sh[k], path + (k,))
            return
        note = f"  [{_leaf_exactness(plan, path)}]" if plan is not None else ""
        print(f"  {'/'.join(path):<40} {str(tuple(sh.shape)):<22} {sp}{note}")

    walk(specs, shapes)


def _print_pareto(cfg, result) -> None:
    """The auto-search dump: profile, frontier table (chosen starred),
    and — when nothing is feasible — the pruning reasons
    (docs/serving.md §plan auto-search)."""
    prof = result.profile
    print(f"plan auto-search: arch={cfg.name} profile={prof.name} "
          f"(rate={prof.arrival_rate:g}/s prompt~{prof.prompt_mean:g} "
          f"out~{prof.output_mean:g} devices={prof.devices} "
          f"hbm={prof.hbm_gb:g}GB max_batch={prof.max_batch})")
    print(f"  {len(result.scores)} candidates, {result.n_feasible} "
          f"feasible, {len(result.frontier)} on the Pareto frontier "
          "(max tok/s, min ttft, min hbm):")
    print(f"  {'candidate':<38} {'tok/s':>9} {'ttft_ms':>9} "
          f"{'hbm':>5} {'lanes':>5} {'repl':>4}")
    for s in result.frontier:
        mark = "*" if result.chosen and s.key == result.chosen.key else " "
        print(f" {mark}{s.key:<38} {s.tok_s:>9.0f} {s.ttft_ms:>9.3f} "
              f"{s.hbm_frac:>5.2f} {s.lanes:>5d} {s.replicas:>4d}")
    if result.chosen is not None:
        print(f"  chosen: {result.chosen.key}")
    else:
        reasons = {}
        for s in result.scores:
            if not s.feasible:
                reasons[s.reason] = reasons.get(s.reason, 0) + 1
        for rsn, n in sorted(reasons.items()):
            print(f"  infeasible x{n}: {rsn}")


def _dryrun(cfg, plan, paged: bool, engine_kw) -> None:
    """Spec-only plan inspection: eval_shape everything, allocate nothing."""
    model = make_model(cfg, remat=False)
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    plan.param_specs = plan.specs_for_params(params_shape)
    print(f"serve --dryrun: arch={cfg.name} mode={plan.mode} "
          f"exact={plan.exact} mesh={dict(plan.mesh.shape)} paged={paged}")
    _print_shardings("params", plan.param_specs, params_shape, plan=plan)
    if paged:
        ps = engine_kw.get("page_size", 16)
        cache_shape = jax.eval_shape(
            lambda: model.init_paged_cache(4, 64, ps, 8,
                                           kv_dtype=engine_kw.get(
                                               "kv_dtype", "bf16")))
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(4, 64))
    cache_specs = plan.specs_for_caches(cache_shape, batch=4,
                                        slot_table=True, paged=paged)
    _print_shardings("serving cache" + (" (paged arena)" if paged else
                                        " (dense slots)"),
                     cache_specs, cache_shape, plan=plan)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    ap.add_argument("--engine", choices=["cb", "wave"], default="cb")
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="max fused decode steps per dispatch (1 = the "
                         "one-dispatch-per-token baseline; docs/perf.md)")
    ap.add_argument("--plan",
                    choices=["none", "serve", "serve_pipeline", "auto"],
                    default="serve",
                    help="Cluster-Builder placement mode (docs/serving.md); "
                         "auto = cost-model search over TP width / stage "
                         "depth / exactness / paging knobs for the --traffic "
                         "profile (docs/serving.md §plan auto-search)")
    ap.add_argument("--traffic", default="",
                    help="traffic-profile JSON for --plan auto (arrival "
                         "rate, prompt/output mix, device + HBM budget); "
                         "default: the built-in default profile "
                         "(benchmarks/profiles/default.json mirrors it)")
    ap.add_argument("--mesh", default="",
                    help="mesh shape, e.g. 1,8 for (data, model) or 8 for "
                         "the serve_pipeline stage axis; default spans all "
                         "visible devices")
    ap.add_argument("--exact", dest="exact", action="store_true",
                    default=True,
                    help="bit-identical serving (default): gather-form TP "
                         "and the drained pipeline schedule")
    ap.add_argument("--no-exact", dest="exact", action="store_false",
                    help="throughput mode: psum-form TP (serve) / request-"
                         "skewed schedule with stage-local KV arenas "
                         "(serve_pipeline); token streams are gated by a "
                         "match-rate band, not equality (docs/serving.md "
                         "§exactness contract)")
    ap.add_argument("--dryrun", action="store_true",
                    help="print the chosen plan's per-leaf shardings and "
                         "exactness modes (params + serving cache) and exit")
    ap.add_argument("--no-plan", action="store_true",
                    help="deprecated alias for --plan none")
    ap.add_argument("--stream",
                    choices=["poisson", "shared-prefix", "bursty"],
                    default="poisson",
                    help="shared-prefix: one system prompt + unique tails "
                         "(the radix prefix cache's target ingress); "
                         "bursty: steady short prompts with long-prompt "
                         "bursts (the --disagg pools' target ingress, "
                         "docs/perf.md §TTFT under burst)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV page length (rows); paged mode is "
                         "auto-enabled for all-attention models under no "
                         "plan or a serve plan (docs/serving.md)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page-pool size (0 = match the dense slot "
                         "table's capacity)")
    ap.add_argument("--kv-dtype", choices=["bf16", "int8"], default="bf16",
                    help="paged KV-cache storage dtype; int8 stores "
                         "quantized pages (+ per-row scales) at ~half the "
                         "HBM per token (docs/serving.md §kv_dtype)")
    ap.add_argument("--disagg", default="",
                    help="disaggregated prefill/decode pools as P:D device "
                         "counts, e.g. --disagg 4:4 — devices [0,P) run "
                         "bucketed prefill and ship completed KV pages "
                         "into the decode pool's arena; the radix tree "
                         "spans both, so prefix hits admit decode-side "
                         "with zero transfers.  Needs the paged cb engine "
                         "under --plan none (docs/serving.md "
                         "§disaggregated serving)")
    ap.add_argument("--draft-config", default="",
                    help="arch name for a speculative-decoding draft model "
                         "(randomly initialised; --reduced applies to it "
                         "too).  Enables greedy speculative decoding: the "
                         "draft proposes --spec-k tokens per lane per "
                         "dispatch and the target verifies them in one "
                         "batched pass (docs/serving.md §speculative "
                         "decoding).  Needs the paged cb engine.")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max drafted tokens per speculative dispatch; the "
                         "scheduler walks a per-lane depth ladder below "
                         "this cap on low acceptance")
    ap.add_argument("--quant-weights", action="store_true",
                    help="serve W8A8: projections/MLP run int8 x int8 -> "
                         "int32 (models/quantized.py); composes with any "
                         "--plan (specs derive from the quantized tree)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve a fleet of N independent engine replicas "
                         "behind the prefix-affinity router (docs/fleet.md)."
                         "  0 (default) = 1, or the auto-chosen replica "
                         "count under --plan auto.  With a plan, --mesh is "
                         "per-replica and the fleet needs replicas x width "
                         "devices (disjoint groups).")
    ap.add_argument("--route",
                    choices=["affinity", "round-robin", "least-loaded"],
                    default="affinity",
                    help="fleet dispatch policy (needs --replicas > 1): "
                         "affinity routes each request to the replica whose "
                         "radix tree should hold its longest prefix, "
                         "falling back to least-loaded; round-robin is the "
                         "control arm (docs/fleet.md)")
    ap.add_argument("--shed-depth", type=int, default=0,
                    help="fleet load shedding: reject a request when every "
                         "replica's admission queue is this deep (x the "
                         "--shed-budget multiplier); 0 = never shed")
    ap.add_argument("--shed-budget", type=float, default=1.0,
                    help="deadline-budget multiplier on --shed-depth")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.no_plan:
        args.plan = "none"

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kv_dtype == "int8" and args.engine != "cb":
        raise SystemExit(
            "serve: --kv-dtype int8 needs the continuous-batching engine "
            "(the wave baseline decodes dense slot rows); drop --engine wave")

    auto_choice = None
    if args.plan == "auto":
        from repro.core.plan_search import TrafficProfile, realize, search
        profile = (TrafficProfile.from_json(args.traffic) if args.traffic
                   else TrafficProfile())
        result = search(cfg, profile)
        _print_pareto(cfg, result)
        if result.chosen is None:
            raise SystemExit(
                "serve: plan auto-search found no feasible candidate for "
                "this traffic profile (pruning reasons above); raise "
                "hbm_gb/devices or quantize")
        auto_choice = result.chosen
        cand = auto_choice.cand
        if cand.paged and args.engine != "cb":
            raise SystemExit("serve: the auto-chosen plan serves from the "
                             "paged pool; drop --engine wave")
        args.plan, args.exact = cand.mode, cand.exact
        if cand.paged:
            args.page_size, args.kv_dtype = cand.page_size, cand.kv_dtype
        args.quant_weights = args.quant_weights or cand.quant_weights

    # --replicas 0 = auto: the plan search's replica count (its explicit
    # TP-width-vs-replica-count axis) when --plan auto chose one, else 1
    if args.replicas == 0:
        args.replicas = (auto_choice.replicas if auto_choice is not None
                         else 1)
    if args.replicas > 1 and args.engine != "cb":
        raise SystemExit("serve: --replicas > 1 serves a fleet of "
                         "continuous-batching engines; drop --engine wave")
    fleet = args.replicas > 1 and not args.dryrun
    if auto_choice is not None and not args.mesh:
        cand = auto_choice.cand
        if fleet:  # per-replica mesh: each engine gets its device group
            args.mesh = (f"1,{cand.tp}" if cand.mode == "serve"
                         else str(cand.stages))
        else:
            args.mesh = (f"{auto_choice.replicas},{cand.tp}"
                         if cand.mode == "serve" else str(cand.stages))

    plan, plans = None, None
    if args.plan != "none":
        if auto_choice is not None and args.dryrun:
            # spec inspection needs no devices: realise on an AbstractMesh
            # of the candidate's own shape (profile.devices may differ
            # from this host)
            plan = realize(cfg, auto_choice)
        elif fleet:
            plans = _fleet_plans(cfg, args)
            plan = plans[0]  # representative: replicas differ only in devices
        else:
            mesh = _parse_mesh(args.mesh, args.plan)
            plan = build_plan(cfg, mesh, mode=args.plan, exact=args.exact)
    # the engine's own paged="auto" predicate, shared so the CLI's int8
    # guard and --dryrun can never disagree with what the engine does
    paged = paged_eligible(cfg, plan) and args.engine == "cb"
    if args.kv_dtype == "int8" and not paged:
        raise SystemExit(
            "serve: --kv-dtype int8 needs the paged pool (all-attention "
            "model under --plan none, serve, or a --no-exact "
            "serve_pipeline)")
    disagg = None
    if args.disagg:
        try:
            p_pool, d_pool = (int(x) for x in args.disagg.split(":"))
        except ValueError:
            raise SystemExit("serve: --disagg takes P:D device counts, "
                             "e.g. --disagg 4:4")
        if args.engine != "cb" or not paged:
            raise SystemExit("serve: --disagg needs the paged cb engine "
                             "(page shipping is the handoff mechanism)")
        if args.plan != "none":
            raise SystemExit("serve: --disagg owns device placement; "
                             "combine it with --plan none")
        if args.replicas > 1:
            raise SystemExit("serve: --disagg does not compose with a "
                             "--replicas fleet yet (cross-host shipping "
                             "lands with the multi-process fleet)")
        if args.draft_config:
            raise SystemExit("serve: --disagg does not compose with "
                             "--draft-config (no draft shipping path yet)")
        disagg = (p_pool, d_pool)
    if args.dryrun:
        if plan is None:
            raise SystemExit("serve: --dryrun inspects a plan; pick "
                             "--plan serve or serve_pipeline")
        _dryrun(cfg, plan, paged,
                dict(page_size=args.page_size, kv_dtype=args.kv_dtype))
        return []

    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    monitor = StragglerMonitor()
    cls = ContinuousBatchingEngine if args.engine == "cb" else WaveEngine
    kw = {}
    if cls is ContinuousBatchingEngine:
        kw["page_size"] = args.page_size
        kw["kv_dtype"] = args.kv_dtype
        if disagg is not None:
            kw["disagg"] = disagg
        if args.num_pages:
            kw["num_pages"] = args.num_pages
        if args.draft_config:
            if not paged:
                raise SystemExit(
                    "serve: --draft-config needs the paged cb engine "
                    "(all-attention model under --plan none or serve)")
            dcfg = get_config(args.draft_config)
            if args.reduced:
                dcfg = dcfg.reduced()
            if dcfg.vocab_size != cfg.vocab_size:
                raise SystemExit(
                    "serve: draft and target must share a vocabulary "
                    f"({dcfg.vocab_size} vs {cfg.vocab_size})")
            draft_model = make_model(dcfg, remat=False)
            draft_params = init_params(dcfg, jax.random.PRNGKey(args.seed + 1))
            kw["spec_config"] = dict(draft_model=draft_model,
                                     draft_params=draft_params,
                                     spec_k=args.spec_k)
    elif args.draft_config:
        raise SystemExit("serve: --draft-config needs --engine cb")
    max_batch = args.max_batch
    if (plan is not None and plan.mode == "serve_pipeline"
            and not plan.exact and cls is ContinuousBatchingEngine):
        n_stages = plan.mesh.shape[plan.axes.stage]
        if max_batch % n_stages:
            max_batch = -(-max_batch // n_stages) * n_stages
            print(f"serve: request-skewed pipeline needs one lane group "
                  f"per stage; max_batch {args.max_batch} -> {max_batch} "
                  f"({n_stages} stages)")
    if fleet:
        from repro.serving.router import FleetConfig, build_fleet
        router = build_fleet(
            model, params, args.replicas, plans=plans,
            config=FleetConfig(route=args.route,
                               shed_depth=args.shed_depth,
                               shed_budget=args.shed_budget),
            max_batch=max_batch, buckets=(16, 32, 64, 128),
            monitor=monitor, decode_horizon=args.decode_horizon,
            quant_weights=args.quant_weights, **kw)
        engine = router
    else:
        engine = cls(model, params, max_batch=max_batch,
                     buckets=(16, 32, 64, 128), plan=plan, monitor=monitor,
                     decode_horizon=args.decode_horizon,
                     quant_weights=args.quant_weights, **kw)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    if args.stream == "shared-prefix":
        stream = shared_prefix_requests(rng, args.requests, cfg.vocab_size,
                                        prefix_len=48, suffix_range=(3, 9),
                                        budgets=args.max_new,
                                        rate=args.rate)
    elif args.stream == "bursty":
        stream = bursty_requests(rng, args.requests, cfg.vocab_size,
                                 budgets=args.max_new, rate=args.rate)
    else:
        stream = poisson_requests(rng, args.requests, cfg.vocab_size,
                                  len_range=(4, 60), budgets=args.max_new,
                                  rate=args.rate)
    for r in stream:
        engine.submit(r)
    done = engine.run()
    wall = time.perf_counter() - t0

    toks = sum(len(r.tokens_out) for r in done)
    if fleet:
        st = engine.stats()
        print(f"serve[fleet]: arch={cfg.name} plan={args.plan} "
              f"replicas={args.replicas} route={args.route} "
              f"requests={len(done)} shed={st['shed']} tokens={toks} "
              f"wall={wall*1e3:.0f}ms throughput={toks/max(wall, 1e-9):.1f}"
              f"tok/s by_kind={st['by_kind']} "
              f"prefix_hit_tokens={st['prefix_hit_tokens']}")
        for p in st["replicas"]:
            print(f"  replica {p['replica']}: routed={p['routed']} "
                  f"admitted={p.get('admitted', 0)} "
                  f"hit_rate={p['prefix_hit_rate']:.2f} "
                  f"wall={p['wall_s']*1e3:.0f}ms")
        for req, reason in engine.shed[:3]:
            print(f"  shed rid={req.rid}: {reason}")
        return done
    lat = sorted((r.t_done - r.t_enqueue) * 1e3 for r in done)
    ttft = sorted((r.t_first_token - r.t_enqueue) * 1e3 for r in done)
    print(f"serve[{args.engine}]: arch={cfg.name} plan={args.plan} "
          f"requests={len(done)} tokens={toks} wall={wall*1e3:.0f}ms "
          f"throughput={toks/wall:.1f}tok/s "
          f"ttft_p50={ttft[len(ttft)//2]:.0f}ms "
          f"p50={lat[len(lat)//2]:.0f}ms p_max={lat[-1]:.0f}ms "
          f"stats={engine.stats}")
    return done


if __name__ == "__main__":
    main()
