"""Serving driver CLI: bring up the continuous-batching engine for any
--arch and serve a Poisson request stream (the paper's kind of deployment:
a line-rate ingress feeding a spatial pipeline that never waits for a full
batch, §8.2).

Requests are submitted with exponential inter-arrival gaps and admitted
into freed KV-cache slots between decode steps; weights and the slot cache
are placed under the Cluster-Builder serve plan.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16 --rate 50
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.cluster_builder import build_plan
from repro.launch.mesh import make_mesh
from repro.models.transformer import init_params, make_model
from repro.runtime.stragglers import StragglerMonitor
from repro.serving.engine import ContinuousBatchingEngine, WaveEngine
from repro.serving.stream import poisson_requests, shared_prefix_requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    ap.add_argument("--engine", choices=["cb", "wave"], default="cb")
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="max fused decode steps per dispatch (1 = the "
                         "one-dispatch-per-token baseline; docs/perf.md)")
    ap.add_argument("--no-plan", action="store_true",
                    help="skip Cluster-Builder placement (debug)")
    ap.add_argument("--stream", choices=["poisson", "shared-prefix"],
                    default="poisson",
                    help="shared-prefix: one system prompt + unique tails "
                         "(the radix prefix cache's target ingress)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV page length (rows); paged mode is "
                         "auto-enabled for all-attention models without a "
                         "plan (docs/serving.md)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV page-pool size (0 = match the dense slot "
                         "table's capacity)")
    ap.add_argument("--kv-dtype", choices=["bf16", "int8"], default="bf16",
                    help="paged KV-cache storage dtype; int8 stores "
                         "quantized pages (+ per-row scales) at ~half the "
                         "HBM per token (docs/serving.md §kv_dtype)")
    ap.add_argument("--quant-weights", action="store_true",
                    help="serve W8A8: projections/MLP run int8 x int8 -> "
                         "int32 (models/quantized.py); with --kv-dtype "
                         "int8 the decode loop is integer-dominant")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.kv_dtype == "int8" and args.engine != "cb":
        raise SystemExit(
            "serve: --kv-dtype int8 needs the continuous-batching engine "
            "(the wave baseline decodes dense slot rows, which have no "
            "quantized variant); drop --engine wave")
    if args.kv_dtype == "int8" and not args.no_plan:
        # int8 KV rides the paged pool, which doesn't compose with plan
        # sharding (slot tables do); same restriction paged="auto" applies
        print("serve: --kv-dtype int8 implies --no-plan (paged KV pool)")
        args.no_plan = True
    if args.quant_weights and not args.no_plan:
        # plan.param_specs are derived from the bf16 leaf tree; the
        # quantized {"q","s"} leaves have no specs yet (engine raises)
        print("serve: --quant-weights implies --no-plan (param specs "
              "cover the bf16 leaf tree only)")
        args.no_plan = True
    plan = None
    if not args.no_plan:
        n_dev = jax.device_count()
        mesh = make_mesh((1, n_dev), ("data", "model"))
        plan = build_plan(cfg, mesh, jax.eval_shape(lambda: params),
                          mode="serve")
    monitor = StragglerMonitor()
    cls = ContinuousBatchingEngine if args.engine == "cb" else WaveEngine
    kw = {}
    if cls is ContinuousBatchingEngine:
        kw["page_size"] = args.page_size
        kw["kv_dtype"] = args.kv_dtype
        if args.num_pages:
            kw["num_pages"] = args.num_pages
    engine = cls(model, params, max_batch=args.max_batch,
                 buckets=(16, 32, 64, 128), plan=plan, monitor=monitor,
                 decode_horizon=args.decode_horizon,
                 quant_weights=args.quant_weights, **kw)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    if args.stream == "shared-prefix":
        stream = shared_prefix_requests(rng, args.requests, cfg.vocab_size,
                                        prefix_len=48, suffix_range=(3, 9),
                                        budgets=args.max_new,
                                        rate=args.rate)
    else:
        stream = poisson_requests(rng, args.requests, cfg.vocab_size,
                                  len_range=(4, 60), budgets=args.max_new,
                                  rate=args.rate)
    for r in stream:
        engine.submit(r)
    done = engine.run()
    wall = time.perf_counter() - t0

    toks = sum(len(r.tokens_out) for r in done)
    lat = sorted((r.t_done - r.t_enqueue) * 1e3 for r in done)
    ttft = sorted((r.t_first_token - r.t_enqueue) * 1e3 for r in done)
    print(f"serve[{args.engine}]: arch={cfg.name} requests={len(done)} "
          f"tokens={toks} wall={wall*1e3:.0f}ms "
          f"throughput={toks/wall:.1f}tok/s "
          f"ttft_p50={ttft[len(ttft)//2]:.0f}ms "
          f"p50={lat[len(lat)//2]:.0f}ms p_max={lat[-1]:.0f}ms "
          f"stats={engine.stats}")
    return done


if __name__ == "__main__":
    main()
