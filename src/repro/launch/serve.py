"""Serving driver CLI: bring up the engine for any --arch and serve a
synthetic request stream (the paper's kind of deployment: batched inference
behind a line-rate ingress, §8).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.models.transformer import init_params, make_model
from repro.runtime.stragglers import StragglerMonitor
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           buckets=(16, 32, 64, 128))
    monitor = StragglerMonitor()

    rng = np.random.default_rng(args.seed)
    lengths = rng.integers(4, 60, args.requests)
    t0 = time.perf_counter()
    for i, n in enumerate(lengths):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=args.max_new))
    done = engine.run()
    wall = time.perf_counter() - t0
    monitor.observe(0, wall)

    toks = sum(len(r.tokens_out) for r in done)
    lat = sorted((r.t_done - r.t_enqueue) * 1e3 for r in done)
    print(f"serve: arch={cfg.name} requests={len(done)} tokens={toks} "
          f"wall={wall*1e3:.0f}ms throughput={toks/wall:.1f}tok/s "
          f"p50={lat[len(lat)//2]:.0f}ms p_max={lat[-1]:.0f}ms "
          f"waves={engine.stats['waves']}")
    return done


if __name__ == "__main__":
    main()
