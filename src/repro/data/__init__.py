from repro.data.pipeline import TokenPipeline, shard_batch  # noqa: F401
