"""Deterministic synthetic token pipeline (sharded, packable).

A seeded first-order Markov chain over the vocabulary (low-entropy rows) so
training loss measurably decreases within a few hundred steps — the
substrate for the end-to-end train example without external data.  Documents
have Zipf-ish variable lengths so the packed (no-padding, paper §7.1) path
has something real to pack.

Batches are host numpy; `shard_batch` places them on the mesh with the
ClusterPlan's data sharding (the input boundary of the SPMD program).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.packing import Packed, pack_sequences


@dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    branching: int = 4  # candidate successors per token (entropy knob)
    pack: bool = False
    mean_doc_len: int = 0  # 0 -> full-row documents

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # chain lives on a vocab prefix
        self._v = v
        self._succ = rng.integers(0, v, size=(v, self.branching))
        self._rng = np.random.default_rng(self.seed + 1)

    def _gen_doc(self, length: int) -> np.ndarray:
        rng = self._rng
        out = np.empty(length + 1, np.int64)
        t = int(rng.integers(0, self._v))
        for i in range(length + 1):
            out[i] = t
            t = int(self._succ[t, rng.integers(0, self.branching)])
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, np.ndarray]:
        b, s = self.batch, self.seq_len
        if not self.pack:
            docs = [self._gen_doc(s) for _ in range(b)]
            arr = np.stack(docs)  # (B, S+1)
            return {
                "tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32),
            }
        # packed mode: variable-length docs, first-fit into B rows
        mean = self.mean_doc_len or max(s // 4, 8)
        seqs: List[np.ndarray] = []
        budget = b * s
        used = 0
        while used < budget * 0.98:
            n = int(np.clip(self._rng.zipf(1.6) * mean // 4, 8, s))
            n = min(n, budget - used)
            if n < 8:
                break
            seqs.append(self._gen_doc(n))
            used += n
        packed = pack_sequences([d[:-1] for d in seqs], s)
        rows = packed.tokens.shape[0]
        if rows > b:
            packed = Packed(packed.tokens[:b], packed.segment_ids[:b],
                            packed.positions[:b], packed.n_segments)
        elif rows < b:
            padf = lambda a, fill: np.concatenate(  # noqa: E731
                [a, np.full((b - rows, s), fill, a.dtype)], 0)
            packed = Packed(padf(packed.tokens, 0),
                            padf(packed.segment_ids, -1),
                            padf(packed.positions, 0), packed.n_segments)
        labels = np.where(
            (packed.segment_ids >= 0)
            & (np.roll(packed.segment_ids, -1, 1) == packed.segment_ids),
            np.roll(packed.tokens, -1, 1), -1).astype(np.int32)
        return {
            "tokens": packed.tokens.astype(np.int32),
            "labels": labels,
            "segment_ids": packed.segment_ids.astype(np.int32),
            "positions": packed.positions.astype(np.int32),
        }


def shard_batch(batch: Dict[str, np.ndarray], mesh: Mesh,
                data_spec_fn) -> Dict[str, jax.Array]:
    """Place a host batch on the mesh with the plan's data sharding."""
    out = {}
    for k, v in batch.items():
        spec = data_spec_fn(v.ndim, v.shape[0])
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
