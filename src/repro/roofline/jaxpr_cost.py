"""Deterministic jaxpr-level FLOP/byte accounting (loop- and remat-aware).

Why not only compiled.cost_analysis()?  XLA's analysis counts while-loop
bodies inconsistently across loop/remat nestings (observed: adding
jax.checkpoint inside a scan changed reported FLOPs by 70x with identical
math), which would make §Perf before/after numbers meaningless.  This
counter walks the jaxpr and weights scan bodies by their trip count, so the
same math always produces the same count, and remat recompute shows up
because the recomputation is explicit in the gradient jaxpr.

Model:
  flops: dot_general = 2*M*N*K*batch; conv counted analogously.
  bytes (two bounds):
    bytes   (fused, the roofline memory term): dot/conv operands+results,
            gather/scatter as 2x the moved slice, concatenate/pad/sort
            outputs, scan carry round-trips and stacked-output writes.
            Elementwise chains are assumed fused into their producers —
            the classic weights+activations roofline traffic.
    bytes_unfused (upper bound, reported alongside): additionally counts
            every other eqn's outputs as one HBM write.

Counts are for the GLOBAL (unpartitioned) program; per-device = /chips,
which ignores uneven-sharding padding (flagged per arch in the table).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

_DTYPE_BYTES = {"int8": 1, "uint8": 1, "bool": 1, "bfloat16": 2,
                "float16": 2, "int16": 2, "uint16": 2, "float32": 4,
                "int32": 4, "uint32": 4, "float64": 8, "int64": 8,
                "uint64": 8, "float8_e4m3fn": 1, "float8_e5m2": 1,
                "uint4": 1, "int4": 1, "key<fry>": 8}


def _nbytes(aval) -> int:
    try:
        size = int(np.prod(aval.shape)) if aval.shape else 1
        return size * _DTYPE_BYTES.get(str(aval.dtype), 4)
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> int:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2 * batch * m * n * contract


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    out_size = int(np.prod(out.shape))
    ker = int(np.prod(rhs.shape[2:])) if len(rhs.shape) > 2 else int(
        np.prod(rhs.shape))
    cin = rhs.shape[1] if len(rhs.shape) > 1 else 1
    return 2 * out_size * ker * cin


_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                       "body_jaxpr")


_MATERIALIZE = ("concatenate", "pad", "sort", "cumsum", "cumlogsumexp",
                "cummax", "rev", "top_k")


def _count(jaxpr, mult: float, acc: Dict[str, float]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            b = (sum(_nbytes(v.aval) for v in eqn.invars)
                 + _nbytes(eqn.outvars[0].aval))
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * b
            acc["bytes_unfused"] += mult * b
            continue
        if prim == "conv_general_dilated":
            b = (sum(_nbytes(v.aval) for v in eqn.invars)
                 + _nbytes(eqn.outvars[0].aval))
            acc["flops"] += mult * _conv_flops(eqn)
            acc["bytes"] += mult * b
            acc["bytes_unfused"] += mult * b
            continue
        if prim == "scan":
            length = eqn.params.get("length", 1)
            num_carry = eqn.params.get("num_carry", 0)
            inner = eqn.params["jaxpr"]
            # carry round-trips per iteration + stacked-output writes (once)
            carry_b = sum(_nbytes(v.aval)
                          for v in inner.jaxpr.outvars[:num_carry])
            ys_b = sum(_nbytes(v.aval) for v in eqn.outvars[num_carry:])
            acc["bytes"] += mult * (2 * length * carry_b + ys_b)
            acc["bytes_unfused"] += mult * (2 * length * carry_b + ys_b)
            _count(inner.jaxpr, mult * length, acc)
            continue
        if prim == "while":
            # trip count unknown statically: count body once (flagged)
            acc["while_ops"] = acc.get("while_ops", 0) + 1
            _count(eqn.params["body_jaxpr"].jaxpr, mult, acc)
            _count(eqn.params["cond_jaxpr"].jaxpr, mult, acc)
            continue
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            best = None
            for br in branches:
                s2 = {"flops": 0.0, "bytes": 0.0, "bytes_unfused": 0.0}
                _count(br.jaxpr, 1.0, s2)
                if best is None or s2["flops"] > best["flops"]:
                    best = s2
            if best is not None:
                for k in ("flops", "bytes", "bytes_unfused"):
                    acc[k] += mult * best[k]
            continue
        if prim in ("scatter", "scatter-add", "scatter_add",
                    "dynamic_update_slice"):
            upd = eqn.invars[-1].aval if prim == "dynamic_update_slice" \
                else eqn.invars[2].aval
            b = 2 * _nbytes(upd)  # read+write the moved slice (in-place)
            acc["bytes"] += mult * b
            acc["bytes_unfused"] += mult * b
            continue
        if prim in ("gather", "dynamic_slice", "take"):
            b = 2 * _nbytes(eqn.outvars[0].aval)
            acc["bytes"] += mult * b
            acc["bytes_unfused"] += mult * b
            continue
        handled = False
        for key in _INNER_JAXPR_PARAMS:
            if key in eqn.params:
                inner = eqn.params[key]
                inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                _count(inner, mult, acc)
                handled = True
                break
        if not handled and "branches" in eqn.params:
            for br in eqn.params["branches"]:
                _count(br.jaxpr, mult, acc)
            handled = True
        if not handled:
            out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
            if prim in _MATERIALIZE:
                acc["bytes"] += mult * 2 * out_b
                acc["bytes_unfused"] += mult * 2 * out_b
            else:
                # elementwise / reduction / layout: fuses in the optimistic
                # model, one write in the unfused bound
                acc["bytes_unfused"] += mult * out_b


def count_costs(fn, *args) -> Dict[str, float]:
    """Trace fn(*args) (ShapeDtypeStructs ok) and count global flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args)
    acc: Dict[str, float] = {"flops": 0.0, "bytes": 0.0, "bytes_unfused": 0.0}
    _count(closed.jaxpr, 1.0, acc)
    return acc
