"""HLO-text parsing: collective bytes per device (loop-weighted).

cost_analysis() has no collective-byte entry, so (per the brief) we parse
the compiled module text and sum the bytes moved by every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

The compiled module is the per-device program, so shapes are already
per-shard; per op we take max(result, operands) bytes as that device's
link traffic.  Collectives inside scan-derived while loops execute
trip-count times: XLA prints `backend_config={"known_trip_count":{"n":N}}`
on the while op, and we propagate multipliers through nested loops
(ENTRY=1, body-of-while = caller_mult * N).

CPU-backend correction: the host backend promotes bf16 dot outputs to f32
and all-reduces BEFORE converting back (reduction computation named
`*_promoted`); on TPU the same all-reduce moves bf16.  Promoted reductions
are therefore counted at half width.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_RE = re.compile(r"= *[^ ]* (" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
# header args may contain nested parens (tuple-typed params): greedy .*
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_EDGE_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _loop_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """mult[comp] = product of trip counts of enclosing while loops,
    propagated through ALL call edges (while bodies weighted by trip count;
    fusions/calls/reduce to_apply weighted 1)."""
    edges: List[Tuple[str, str, float]] = []
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            wm = _WHILE_RE.search(line)
            trip = None
            if wm:
                t = _TRIP_RE.search(line)
                trip = float(t.group(1)) if t else 1.0
                edges.append((name, wm.group(1), trip))
            for em in _EDGE_RE.finditer(line):
                if wm and em.group(1) == wm.group(1):
                    continue  # already added with its trip count
                if em.group(1) != name:
                    edges.append((name, em.group(1), 1.0))
    mult: Dict[str, float] = {n: 1.0 for n in comps}
    # fixpoint: take MAX over callers (a comp reached from both a loop and
    # entry keeps the loop weighting)
    for _ in range(12):
        changed = False
        for caller, body, n in edges:
            new = mult.get(caller, 1.0) * n
            if new > mult.get(body, 1.0):
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (loop-weighted)."""
    comps = _computations(hlo_text)
    mult = _loop_multipliers(comps)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0.0
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0)
        for line in lines:
            cm = _CALL_RE.search(line)
            if cm is None:
                continue
            lhs, _, rhs = line.partition("=")
            res_b = _shape_bytes(rhs.partition("(")[0])
            opnd_b = _shape_bytes(rhs.partition("(")[2].partition(")")[0])
            b = max(res_b, opnd_b)
            if "_promoted" in line:
                b //= 2  # CPU bf16->f32 promotion artifact (see docstring)
            out[cm.group(1)] += b * m
            out["count"] += m
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
