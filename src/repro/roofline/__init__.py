from repro.roofline.analysis import RooflineTerms, analyze  # noqa: F401
from repro.roofline.hlo import collective_bytes  # noqa: F401
