"""Three-term roofline from compiled dry-run artifacts (brief §Roofline).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory     = HLO_bytes_per_device / HBM_bw_per_chip
  collective = collective_bytes_per_device / link_bw_per_chip

cost_analysis() of a partitioned executable describes the per-device
program, so per-chip constants divide directly.  Hardware: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (brief constants).

MODEL_FLOPS (analytic useful work) = 6*N_active*tokens for training,
2*N_active*tokens for inference; the ratio MODEL_FLOPS / (HLO_FLOPs*chips)
exposes remat/dispatch waste.  This module is the §9-style projection the
paper performs for Versal: measured proof-of-concept -> arithmetic estimate
on the target part.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS_BF16 = 197e12  # per chip
PEAK_FLOPS_INT8 = 394e12  # v5e int8 is 2x bf16 (paper C4: the int8 payoff)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-chip effective link bandwidth)


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: float
    chips: int
    dominant: str = ""
    model_flops_ratio: float = 0.0  # useful / compiled (x chips)
    roofline_fraction: float = 0.0  # bound_term / sum-ish utilization proxy

    def as_dict(self) -> Dict:
        return self.__dict__.copy()


def model_flops(cfg, cell, tokens: Optional[int] = None) -> float:
    """Analytic useful FLOPs for one step of this (arch, cell).

    Embedding parameters only do real math where the LM head matmul runs:
    every position in training, only the last position in prefill, every
    emitted token in decode.  (Charging 2*embed*tokens to prefill put the
    'useful' count above the compiled count for big-vocab archs.)"""
    n_act = cfg.active_param_count()
    n_body = n_act - cfg.embed_params()
    head = cfg.vocab_size * cfg.d_model
    if tokens is None:
        if cell.kind in ("train", "prefill"):
            tokens = cell.global_batch * cell.seq_len
        else:  # decode: one new token per sequence
            tokens = cell.global_batch
    if cell.kind == "train":
        flops = 6.0 * (n_body + head) * tokens
    elif cell.kind == "prefill":
        flops = 2.0 * n_body * tokens + 2.0 * head * cell.global_batch
    else:
        flops = 2.0 * (n_body + head) * tokens
    # attention KV term (dominant extra for decode against long caches)
    if cell.kind == "decode":
        s_kv = (min(cell.seq_len, cfg.local_window)
                if cfg.local_window else cell.seq_len)
        attn_layers = sum(1 for i in range(cfg.n_layers)
                          if cfg.block_kind(i) == "attn")
        # scores (2 flops/elt) + PV (2 flops/elt) over the whole cache
        flops += (4.0 * cell.global_batch * s_kv
                  * cfg.n_heads * cfg.head_dim * attn_layers)
    return flops


def analyze(flops_per_device: float, bytes_per_device: float,
            coll_bytes_per_device: float, chips: int,
            model_flops_total: float,
            int8: bool = False) -> RooflineTerms:
    peak = PEAK_FLOPS_INT8 if int8 else PEAK_FLOPS_BF16
    t = RooflineTerms(
        compute_s=flops_per_device / peak,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=coll_bytes_per_device / ICI_BW,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        model_flops_total=model_flops_total,
        chips=chips,
    )
    terms = {"compute": t.compute_s, "memory": t.memory_s,
             "collective": t.collective_s}
    t.dominant = max(terms, key=terms.get)
    compiled_total = flops_per_device * chips
    t.model_flops_ratio = (model_flops_total / compiled_total
                           if compiled_total else 0.0)
    # utilization proxy: useful-compute time / dominant-term time
    useful_s = model_flops_total / (chips * peak)
    bound_s = max(terms.values())
    t.roofline_fraction = useful_s / bound_s if bound_s else 0.0
    return t


def suggest(t: RooflineTerms) -> str:
    """One-sentence 'what moves the dominant term down' (brief §Roofline)."""
    if t.dominant == "compute":
        if t.model_flops_ratio < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat "
                    "recompute / dead dispatch compute before anything else")
        return ("compute-bound near peak: int8 (2x MXU) or fewer FLOPs "
                "(MoE/sparsity) are the only levers")
    if t.dominant == "memory":
        return ("HBM-bound: fuse elementwise chains, cache weights in VMEM "
                "across grid steps (bigger kernel blocks), or quantize "
                "weights/KV to int8 to halve bytes")
    return ("collective-bound: reshard to shrink the largest all-gather, "
            "use hierarchical (gateway) schedules across pods, and overlap "
            "collectives with compute (async)")
