"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.roofline.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    recs = []
    for fp in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fp) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


CELL_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
              "long_500k": 3}


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = ["| arch | cell | status | compile | temp/chip | args/chip | "
            "collective bytes/chip | coll ops |",
            "|---|---|---|---|---|---|---|---|"]
    sel = [r for r in recs if r.get("mesh", "").startswith(
        "multipod" if mesh == "multi" else "pod")]
    sel.sort(key=lambda r: (r["arch"], CELL_ORDER.get(r["cell"], 9)))
    for r in sel:
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['cell']} | SKIP | - | - | - |"
                        f" - | - |")
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['cell']} | **FAIL** | - | - |"
                        f" - | - | - |")
            continue
        m = r["memory_analysis"]
        c = r["collectives"]
        rows.append(
            f"| {r['arch']} | {r['cell']} | OK | {r['compile_s']:.0f}s "
            f"| {fmt_bytes(m.get('temp_size_in_bytes'))} "
            f"| {fmt_bytes(m.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(c.get('total'))} | {int(c.get('count', 0))} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = ["| arch | cell | compute | memory | collective | dominant | "
            "MODEL_FLOPS | useful/compiled | roofline frac | "
            "what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    sel = [r for r in recs if r.get("mesh", "").startswith("pod")
           and r["status"] == "OK"]
    sel.sort(key=lambda r: (r["arch"], CELL_ORDER.get(r["cell"], 9)))
    for r in sel:
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['cell']} | {fmt_s(t['compute_s'])} "
            f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {t['model_flops_total']:.2e} "
            f"| {t['model_flops_ratio']:.3f} | {t['roofline_fraction']:.3f} "
            f"| {r.get('suggestion', '')} |")
    return "\n".join(rows)


def skip_table(recs: List[Dict]) -> str:
    rows = ["| arch | cell | reason |", "|---|---|---|"]
    seen = set()
    for r in recs:
        if r["status"] == "SKIP" and (r["arch"], r["cell"]) not in seen:
            seen.add((r["arch"], r["cell"]))
            rows.append(f"| {r['arch']} | {r['cell']} | "
                        f"{r.get('skip_reason','')[:120]} |")
    return "\n".join(rows)


def summarize(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r["status"] == "OK"]
    fail = [r for r in recs if r["status"] == "FAIL"]
    skip = [r for r in recs if r["status"] == "SKIP"]
    worst = sorted((r for r in ok if r["mesh"].startswith("pod")),
                   key=lambda r: r["roofline"]["roofline_fraction"])
    most_coll = sorted(
        (r for r in ok if r["mesh"].startswith("pod")),
        key=lambda r: -(r["roofline"]["collective_s"]
                        / max(sum((r["roofline"]["compute_s"],
                                   r["roofline"]["memory_s"],
                                   r["roofline"]["collective_s"])), 1e-30)))
    return {"n_ok": len(ok), "n_fail": len(fail), "n_skip": len(skip),
            "worst_fraction": [(r["arch"], r["cell"],
                                r["roofline"]["roofline_fraction"])
                               for r in worst[:5]],
            "most_collective_bound": [(r["arch"], r["cell"])
                                      for r in most_coll[:5]]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    recs = load(args.dir)
    parts = [
        "### Dry-run table — single pod (16x16 = 256 chips)\n",
        dryrun_table(recs, "single"),
        "\n### Dry-run table — multi-pod (2x16x16 = 512 chips)\n",
        dryrun_table(recs, "multi"),
        "\n### Skipped cells\n",
        skip_table(recs),
        "\n### Roofline (single-pod, per brief)\n",
        roofline_table(recs),
        "\n### Summary\n",
        "```json\n" + json.dumps(summarize(recs), indent=1) + "\n```",
    ]
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
