# Compute hot-spots the paper optimizes (I-BERT integer encoder, §7):
# int8 GEMM + Quant, i-Softmax, i-LayerNorm, i-GELU — Pallas TPU kernels with
# pure-jnp oracles in ref.py and jit'd public wrappers in ops.py.
from repro.kernels import ops, ref  # noqa: F401
