"""Pallas TPU kernel: split-KV single-query flash attention (decode).

Decode attention is the serving hot loop's memory-bound core: one query row
per slot against the whole KV cache.  The dense jnp path materializes the
(B, H, 1, S) score tensor in HBM and reads the GQA-expanded cache; this
kernel streams the cache through VMEM once — HBM traffic = K + V + Q + O,
with Q and O negligible (one row per head group).

Grid: (batch, kv_heads, Sk/bs) with the KV-split axis innermost, so each
(batch, kv head) pair walks its splits sequentially while the per-split
(m, l, acc) partials stay resident in VMEM scratch; the final split runs the
reduction epilogue (normalize by l, cast, write O).  All `q_per_kv` query
heads of a KV group ride in one block — the group dim is the sublane axis,
so GQA costs no extra cache reads.

Masking is slot-metadata driven, matching the serving cache contract
(models/attention.py):

  * `kpos` carries each cache slot's absolute position; the never-written
    sentinel (2^30) can never satisfy ``kpos <= qpos`` and is excluded by
    the causal test — no separate validity plane needed;
  * sliding windows test ``qpos - kpos < window`` against the same absolute
    positions, so ring-buffer caches (slot = pos % window) need no unrolling;
  * `active` gates whole rows: an inactive serving slot contributes an
    all-masked row and the epilogue emits exact zeros (l == 0), never NaN.

`paged_flash_decode` is the same online-softmax body over a *paged* KV
arena: the per-lane page table is scalar-prefetched and indexed inside the
BlockSpec index maps, so walking a lane's pages in logical order is just
the grid's DMA schedule — the gather costs nothing beyond the block
fetches the dense kernel already does, and radix-shared prefix pages are
fetched per lane that names them, never duplicated in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 256  # default KV split length (sublane dim of the k/v blocks)
NEG_INF = -1e30
KPOS_SENTINEL = 2 ** 30  # never-written cache slot (models/attention.py)


def _kernel(qpos_ref, active_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_s: int, bs: int, window: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (G, hd) — the kv group's query heads
    k = k_ref[0, :, 0, :]  # (bs, hd)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, bs)

    qpos = qpos_ref[0, 0]
    kpos = kpos_ref[0]  # (bs,) absolute positions; 2^30 = never written
    msk = kpos[None, :] <= qpos  # causal; also rejects the sentinel
    if window:
        msk &= qpos - kpos[None, :] < window
    msk &= active_ref[0, 0] != 0

    s = jnp.where(msk, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # p from the mask, not from s > threshold: a fully-masked split leaves
    # m_new at NEG_INF and exp(s - m_new) would be exp(0) = 1 garbage
    p = jnp.where(msk, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == n_s - 1)
    def _epilogue():
        # combine the split partials: normalize by the running l.  l == 0
        # (inactive slot / fresh cache, every key masked) yields exact 0.
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel(pt_ref, qpos_ref, active_ref, q_ref, k_ref, v_ref,
                  kpos_ref, o_ref, m_ref, l_ref, acc_ref, *, n_p: int):
    """Same online-softmax body as `_kernel`, but the KV block walked at
    grid step j is whichever *page* the lane's page table names — the
    gather happens in the BlockSpec index map (scalar-prefetched page
    table), so the kernel body never sees page indirection at all."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (G, hd)
    k = k_ref[0, :, 0, :]  # (ps, hd) — the page named by pt[b, j]
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, ps)

    qpos = qpos_ref[0, 0]
    kpos = kpos_ref[0]  # (ps,) absolute positions; 2^30 = never written
    # causal test rejects the sentinel, so trash/unwritten page slots and
    # out-of-range page-table entries are unreachable by construction
    msk = kpos[None, :] <= qpos
    msk &= active_ref[0, 0] != 0

    s = jnp.where(msk, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(msk, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == n_p - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel_q(pt_ref, qpos_ref, active_ref, q_ref, k_ref, v_ref,
                    ks_ref, vs_ref, kpos_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, n_p: int):
    """`_paged_kernel` over an *int8* KV arena: the page named by pt[b, j]
    arrives as int8 k/v tiles plus their per-row f32 scales, and the tiles
    are dequantized in VMEM right before the dots — HBM moves ~half the
    bytes of the bf16 arena (int8 values + one f32 scale per row per kv
    head) while the online-softmax recurrence is unchanged and stays f32."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    # (ps, hd) int8 * (ps, 1) f32 scale -> dequantized page tile in VMEM;
    # the scales ride the same page-table indirection as kpos, so a
    # radix-shared page dequantizes identically for every lane reading it
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0]  # (ps, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, ps)

    qpos = qpos_ref[0, 0]
    kpos = kpos_ref[0]  # (ps,) absolute positions; 2^30 = never written
    msk = kpos[None, :] <= qpos  # causal; also rejects the sentinel
    msk &= active_ref[0, 0] != 0

    s = jnp.where(msk, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(msk, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == n_p - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode_q(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         kpos: jax.Array, page_table: jax.Array,
                         qpos: jax.Array, active: jax.Array, *,
                         interpret: bool = False) -> jax.Array:
    """Split-KV decode over a *quantized* (int8) paged KV arena.

    Same contract as `paged_flash_decode` except k/v are int8 arenas and
    k_scale/v_scale: (P, ps, KVH) f32 carry one symmetric scale per cache
    row per kv head (core/quant.kv_quantize).  Scales are fetched through
    the same scalar-prefetched page-table indirection as the kpos plane,
    and the tiles are dequantized in VMEM just before the dots, so the
    kernel's HBM traffic is the int8 bytes + scales — ~half the bf16
    arena's — while the softmax recurrence runs in f32 exactly like the
    unquantized kernel.
    """
    b, kvh, g, hd = q.shape
    ps = k.shape[1]
    maxp = page_table.shape[1]
    grid = (b, kvh, maxp)
    kern = functools.partial(_paged_kernel_q, n_p=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j, pt: (b, 0),
                         memory_space=pltpu.SMEM),  # qpos
            pl.BlockSpec((1, 1), lambda b, h, j, pt: (b, 0),
                         memory_space=pltpu.SMEM),  # active
            pl.BlockSpec((1, 1, g, hd), lambda b, h, j, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, pt: (pt[b, j], 0, h, 0)),  # k int8
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, pt: (pt[b, j], 0, h, 0)),  # v int8
            pl.BlockSpec((1, ps, 1),
                         lambda b, h, j, pt: (pt[b, j], 0, h)),  # k_scale
            pl.BlockSpec((1, ps, 1),
                         lambda b, h, j, pt: (pt[b, j], 0, h)),  # v_scale
            pl.BlockSpec((1, ps), lambda b, h, j, pt: (pt[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, j, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running sum l
            pltpu.VMEM((g, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(page_table, qpos, active, q, k, v, k_scale, v_scale, kpos)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                       kpos: jax.Array, page_table: jax.Array,
                       qpos: jax.Array, active: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """Split-KV decode over a *paged* KV arena.

    q: (B, KVH, G, hd) pre-scaled grouped queries; k/v: (P, ps, KVH, hd)
    global page arenas shared by every lane; kpos: (P, ps) int32 absolute
    positions per arena slot (2^30 = never written); page_table:
    (B, MAXP) int32 — lane b's logical KV positions [j*ps, (j+1)*ps) live
    in arena page page_table[b, j].  Entries may repeat across lanes
    (radix-shared prefixes) and unused entries may point anywhere whose
    kpos are all sentinel (the allocator's trash page 0).  qpos: (B, 1)
    int32; active: (B, 1) int32 row gate.  Returns (B, KVH, G, hd).

    Grid: (batch, kv_heads, MAXP) with pages innermost; the page table is
    scalar-prefetched and indexed in the k/v/kpos BlockSpec index maps, so
    the per-page DMA *is* the gather — the kernel body is identical to the
    dense split-KV kernel's online softmax.
    """
    b, kvh, g, hd = q.shape
    ps = k.shape[1]
    maxp = page_table.shape[1]
    grid = (b, kvh, maxp)
    kern = functools.partial(_paged_kernel, n_p=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j, pt: (b, 0),
                         memory_space=pltpu.SMEM),  # qpos
            pl.BlockSpec((1, 1), lambda b, h, j, pt: (b, 0),
                         memory_space=pltpu.SMEM),  # active
            pl.BlockSpec((1, 1, g, hd), lambda b, h, j, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, pt: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, pt: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, pt: (pt[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, j, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running sum l
            pltpu.VMEM((g, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(page_table, qpos, active, q, k, v, kpos)


@functools.partial(
    jax.jit, static_argnames=("window", "bs", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kpos: jax.Array, qpos: jax.Array, active: jax.Array, *,
                 window: int = 0, bs: int = BS,
                 interpret: bool = False) -> jax.Array:
    """q: (B, KVH, G, hd) pre-scaled grouped queries; k/v: (B, Sk, KVH, hd).

    kpos: (B, Sk) int32 absolute key positions (2^30 = never written);
    qpos: (B, 1) int32 query position; active: (B, 1) int32 row gate.
    Sk % bs == 0 (ops.py pads with the kpos sentinel).  Returns
    (B, KVH, G, hd) in q.dtype.  ops.py handles layout, padding and GQA
    head-group reshapes.
    """
    b, kvh, g, hd = q.shape
    sk = k.shape[1]
    assert sk % bs == 0, (sk, bs)
    n_s = sk // bs
    grid = (b, kvh, n_s)
    kern = functools.partial(_kernel, n_s=n_s, bs=bs, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0),
                         memory_space=pltpu.SMEM),  # qpos
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0),
                         memory_space=pltpu.SMEM),  # active
            pl.BlockSpec((1, 1, g, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running sum l
            pltpu.VMEM((g, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qpos, active, q, k, v, kpos)
