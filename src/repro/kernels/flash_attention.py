"""Pallas TPU kernel: fused causal flash attention (bf16/f32).

The §Perf C analysis showed the 32k-prefill memory term is dominated by the
online-softmax carry (m, l, acc) round-tripping through HBM once per KV
chunk in the jax.lax.scan formulation.  This kernel keeps the carry in VMEM
scratch across the KV-block loop — the textbook flash-attention memory
profile: HBM traffic = Q + K + V + O only.

Grid: (batch*heads, Sq/bq, Sk/bk) with the KV block innermost so the
(bq, hd) f32 accumulator and (bq,) m/l statistics stay resident in VMEM for
the whole row of KV blocks.  Causal masking is positional (absolute q/k
offsets), so the same kernel serves prefill (q_offset=0) and windowed use.

Tile defaults: bq=bk=256, hd<=256 -> q(256,hd)+k/v(256,hd)bf16 + acc f32
~= 0.5 MB VMEM, MXU-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ, BK = 256, 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_k: int, bq: int, bk: int, causal: bool, scale: float,
            kv_len: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, hd)
    k = k_ref[0]  # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        i = pl.program_id(1)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    if kv_len % bk:  # padded tail block: mask the pad keys
        s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "kv_len"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = BQ, bk: int = BK,
                    kv_len: int = 0, interpret: bool = False) -> jax.Array:
    """q,k,v: (BH, S, hd) — batch*heads flattened; S % bq == S % bk == 0.

    kv_len: true (unpadded) KV length; pad keys beyond it are masked.
    Returns (BH, S, hd) in q.dtype.  ops.py handles GQA head grouping,
    padding to tile multiples, and (B, S, H, hd) layout.
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_k = sk // bk
    grid = (bh, sq // bq, n_k)
    scale = 1.0 / math.sqrt(hd)
    kern = functools.partial(_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal,
                             scale=scale, kv_len=kv_len or sk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
