"""Pallas TPU kernel: integer softmax (I-BERT i-exp + fixed-point normalize).

Paper Fig. 10 layer 2 (Softmax modules, Kern_4..15).  Row-blocked: each grid
step normalizes (block_rows, C) int32 scores held in VMEM.  All math is
int32; the only float ops are the scale-derived constants and the shift
selection (one log2 per row), matching ibert_ops.i_softmax bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ibert_ops import (
    _EXP_A, _EXP_B, _EXP_C, _EXP_CLAMP, _LN2, SOFTMAX_OUT_BITS, _to_i32,
)

BLOCK_ROWS = 8


def _kernel(x_ref, s_ref, o_ref):
    q = x_ref[...]
    scale = s_ref[0, 0]
    q_max = jnp.max(q, axis=-1, keepdims=True)
    qn = q - q_max
    q_clamp = _to_i32(jnp.floor(_EXP_CLAMP / scale))
    qn = jnp.maximum(qn, q_clamp)
    q_ln2 = jnp.maximum(_to_i32(jnp.floor(_LN2 / scale)), 1)
    z = (-qn) // q_ln2
    p = qn + z * q_ln2
    q_b = _to_i32(jnp.floor(_EXP_B / scale))
    q_c = _to_i32(jnp.floor(_EXP_C / (_EXP_A * scale * scale)))
    t = p + q_b
    q_exp = (t * t + q_c) >> z

    q_sum = jnp.maximum(jnp.sum(q_exp, axis=-1, keepdims=True), 1)
    sh = jnp.maximum(
        jnp.ceil(jnp.log2(q_sum.astype(jnp.float32) + 1.0)) - 16, 0
    ).astype(jnp.int32)
    q_e2 = q_exp >> sh
    q_s2 = jnp.maximum(q_sum >> sh, 1)
    factor = (2 ** 29) // q_s2
    o_ref[...] = (q_e2 * factor) >> (29 - SOFTMAX_OUT_BITS)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def i_softmax(q: jax.Array, scale: jax.Array, *, block_rows: int = BLOCK_ROWS,
              interpret: bool = False) -> jax.Array:
    """q: (R, C) int32 scores; scale f32 scalar -> (R, C) int32 probs @2^-14."""
    r, c = q.shape
    assert r % block_rows == 0, (r, block_rows)
    return pl.pallas_call(
        _kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        interpret=interpret,
    )(q, scale.reshape(1, 1).astype(jnp.float32))
