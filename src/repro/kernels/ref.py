"""Pure-jnp oracles for every Pallas kernel in this package.

Each Pallas kernel in kernels/ must agree with the function of the same name
here — exactly (integer kernels) or to tight tolerance (float kernels).  The
integer oracles are the I-BERT algorithms from repro.core.ibert_ops; the
matmul oracle is the INT8xINT8->INT32 contract from repro.core.quant.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ibert_ops as _io
from repro.core.quant import requantize as _requantize


def int8_matmul(a: jax.Array, b: jax.Array, s_a: jax.Array, s_b: jax.Array,
                bias: Optional[jax.Array] = None,
                s_out: Optional[jax.Array] = None) -> jax.Array:
    """INT8 (M,K) x INT8 (K,N) -> INT32 accum (+ int32 bias at scale s_a*s_b),
    optionally requantized to INT8 at s_out.  The paper's Linear module
    (Matrix-Multiply + Bias Addition + Quant, Fig. 10 layers 0/4/5)."""
    acc = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    if bias is not None:
        acc = acc + bias[None, :]
    if s_out is None:
        return acc
    return _requantize(acc, s_a * s_b, s_out)


def i_softmax_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Integer softmax over the last axis; returns int32 probs at 2^-14."""
    out, _ = _io.i_softmax(q.astype(jnp.int32), scale, axis=-1)
    return out


def i_layernorm_rows(q8: jax.Array, q_gamma: jax.Array, q_beta: jax.Array,
                     s_gamma: jax.Array) -> jax.Array:
    """Integer LayerNorm over the last axis (input int8-range int32)."""
    prep = _io.LNParams(q_gamma, s_gamma, q_beta,
                        jnp.float32(2.0 ** (-_io.LN_NORM_SHIFT)) * s_gamma)
    out, _ = _io.i_layernorm(q8.astype(jnp.int32), prep)
    return out


def i_gelu_elem(q: jax.Array, scale: jax.Array) -> jax.Array:
    out, _ = _io.i_gelu(q.astype(jnp.int32), scale)
    return out


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kpos: jax.Array, qpos: jax.Array,
                 active: Optional[jax.Array] = None,
                 window: int = 0) -> jax.Array:
    """Single-query (decode) attention oracle for the split-KV kernel.

    q: (B, H, hd) pre-scaled by 1/sqrt(hd); k/v: (B, Sk, KVH, hd) with
    H = G * KVH (GQA — KV is never expanded); kpos: (B, Sk) int32 absolute
    key positions where 2^30 marks never-written cache slots; qpos: (B,)
    int32 absolute query position; active: optional (B,) bool row gate
    (inactive rows return exact zeros); window: sliding-window width
    (0 = unwindowed).  Masking is causal: ``kpos <= qpos`` — the sentinel
    can never pass, so fresh cache slots are unreachable by construction.
    """
    b, h, hd = q.shape
    kvh = k.shape[2]
    q5 = q.reshape(b, kvh, h // kvh, hd)
    s = jnp.einsum("bngd,bknd->bngk", q5, k).astype(jnp.float32)
    msk = kpos[:, None] <= qpos[:, None, None]  # (B, 1, Sk)
    if window:
        msk &= qpos[:, None, None] - kpos[:, None] < window
    if active is not None:
        msk &= active[:, None, None]
    msk = msk[:, :, None, :]  # (B, 1, 1, Sk) vs scores (B, KVH, G, Sk)
    s = jnp.where(msk, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(msk, -1, keepdims=True), p, 0.0)
    out = jnp.einsum("bngk,bknd->bngd", p.astype(q.dtype), v)
    return out.reshape(b, h, hd)


def paged_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                       kpos: jax.Array, page_table: jax.Array,
                       qpos: jax.Array,
                       active: Optional[jax.Array] = None) -> jax.Array:
    """Oracle for the paged split-KV decode kernel: gather, then decode.

    q: (B, H, hd) pre-scaled; k/v: (P, ps, KVH, hd) global page arenas;
    kpos: (P, ps) absolute positions (2^30 = never written); page_table:
    (B, MAXP) int32 (entries may repeat across lanes — shared prefix
    pages).  The gathered per-lane cache is laid out exactly like the
    dense slot cache (logical position p at row p), so on equal logical
    lengths this oracle is *bitwise* identical to `flash_decode` over the
    equivalent dense cache — the property the engine equality tests lean
    on.
    """
    b = q.shape[0]
    kvh, hd = k.shape[2], k.shape[3]
    kg = k[page_table].reshape(b, -1, kvh, hd)
    vg = v[page_table].reshape(b, -1, kvh, hd)
    kpg = kpos[page_table].reshape(b, -1)
    return flash_decode(q, kg, vg, kpg, qpos, active=active)


def paged_flash_decode_q(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         kpos: jax.Array, page_table: jax.Array,
                         qpos: jax.Array,
                         active: Optional[jax.Array] = None) -> jax.Array:
    """Oracle for the *quantized* paged decode kernel: gather int8 pages
    and their scales through the table, dequantize to f32
    (core/quant.kv_dequantize — bitwise the kernel's in-VMEM dequant),
    then run the dense decode oracle.

    k/v: (P, ps, KVH, hd) int8 arenas; k_scale/v_scale: (P, ps, KVH) f32
    per-row per-kv-head scales (shared prefix pages share scales by
    construction — they live in the arena, not per lane).  The dequantized
    values stay f32 through the dots, matching the kernel body, so the two
    impls agree to float tolerance and lanes sharing pages see identical
    keys.
    """
    from repro.core.quant import kv_dequantize

    b = q.shape[0]
    kvh, hd = k.shape[2], k.shape[3]
    kg = kv_dequantize(k[page_table], k_scale[page_table]).reshape(
        b, -1, kvh, hd)
    vg = kv_dequantize(v[page_table], v_scale[page_table]).reshape(
        b, -1, kvh, hd)
    kpg = kpos[page_table].reshape(b, -1)
    # q joins the dequantized values in f32 so the PV dot runs f32 like the
    # kernel body (ops.py casts the result back to q.dtype)
    return flash_decode(q.astype(jnp.float32), kg, vg, kpg, qpos,
                        active=active)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    segment_ids: Optional[jax.Array] = None) -> jax.Array:
    """Float attention oracle for the blocked-attention kernel.

    q,k,v: (S, H) per head slice (already scaled by 1/sqrt(d)).  segment_ids
    implement the paper's no-padding packed sequences (§7.1): tokens attend
    only within their own segment.
    """
    s = jnp.einsum("qh,kh->qk", q, k).astype(jnp.float32)
    sq, sk = q.shape[0], k.shape[0]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
    if segment_ids is not None:
        qseg, kseg = segment_ids
        mask &= qseg[:, None] == kseg[None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1, keepdims=True), p, 0.0)
    return jnp.einsum("qk,kh->qh", p, v.astype(jnp.float32)).astype(q.dtype)
