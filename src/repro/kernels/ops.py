"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; elsewhere
they run in interpret mode (exact same kernel body, executed by the Pallas
interpreter) or fall back to the pure-jnp oracle (`impl="ref"`).  All
wrappers apply the paper's *minimum padding* rule (§7.1): operands are padded
only up to the tile granularity the hardware needs (MXU 128 lanes here,
NUM_PE there) and the padding is stripped from the result.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ibert_ops import LNParams
from repro.kernels import ref as _ref
from repro.kernels import int8_matmul as _mm
from repro.kernels import i_gelu as _ig
from repro.kernels import i_layernorm as _iln
from repro.kernels import i_softmax as _ism

_IMPL = None


def default_impl() -> str:
    """'pallas' on TPU, 'interpret' on CPU unless overridden.

    Interpret mode runs the *same kernel bodies* through the Pallas
    interpreter, so CPU CI exercises the real kernels; the pure-jnp oracle
    stays reachable via ``set_impl("ref")`` (or per-call ``impl="ref"``).
    """
    global _IMPL
    if _IMPL is None:
        _IMPL = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return _IMPL


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("pallas", "interpret", "ref")
    _IMPL = impl


@contextlib.contextmanager
def pinned_impl(impl: str):
    """Pin the process-wide impl inside a block, restoring the previous
    value (including the unresolved None) on exit — the benches and tests
    that compare token streams across engines pin one impl on both sides
    (docs/perf.md §impl selection)."""
    global _IMPL
    prev = _IMPL
    set_impl(impl)
    try:
        yield
    finally:
        _IMPL = prev


# interpret mode replays the grid at trace time (one kernel-body trace per
# program), so routing decisions must bound the grid: ~2ms/program means
# 1024 keeps first-call latency under a few seconds for CPU CI while the
# 32k dry-run cells (10^5+ programs) fall back to the jnp paths.
INTERPRET_MAX_GRID = 1024


def fused_grid_ok(impl: str, *dims: int) -> bool:
    """Is a Pallas kernel with this grid routable under `impl`?"""
    if impl == "pallas":
        return True
    n = 1
    for d in dims:
        n *= d
    return n <= INTERPRET_MAX_GRID


def _pad_to(x: jax.Array, mult, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def int8_matmul(a: jax.Array, b: jax.Array, s_a, s_b,
                s_out=None, bias: Optional[jax.Array] = None,
                impl: Optional[str] = None) -> jax.Array:
    """Minimum-padded INT8 GEMM (+bias at s_a*s_b, + optional requant to s_out)."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.int8_matmul(a, b, s_a, s_b, bias=bias, s_out=s_out)
    m, k = a.shape
    _, n = b.shape
    bm, bn = min(_mm.BM, _rup(m, 8)), min(_mm.BN, _rup(n, 128))
    bk = min(_mm.BK, _rup(k, 128))
    ap = _pad_to(_pad_to(a, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bk, 0), bn, 1)
    biasp = _pad_to(bias, bn, 0) if bias is not None else None
    out = _mm.int8_matmul(
        ap, bp, jnp.asarray(s_a, jnp.float32), jnp.asarray(s_b, jnp.float32),
        s_out=None if s_out is None else jnp.asarray(s_out, jnp.float32),
        bias=biasp, bm=bm, bn=bn, bk=bk,
        requant=s_out is not None, interpret=impl == "interpret",
    )
    return out[:m, :n]


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def i_gelu(q: jax.Array, scale, impl: Optional[str] = None) -> jax.Array:
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.i_gelu_elem(q, scale)
    shape = q.shape
    q2 = q.reshape(-1, shape[-1])
    rows = q2.shape[0]
    br = min(_ig.BLOCK_ROWS, rows)
    q2 = _pad_to(q2, br, 0)
    out = _ig.i_gelu(q2, jnp.asarray(scale, jnp.float32), block_rows=br,
                     interpret=impl == "interpret")
    return out[:rows].reshape(shape)


def i_softmax(q: jax.Array, scale, impl: Optional[str] = None) -> jax.Array:
    """Integer softmax over last axis -> int32 probs at 2^-14."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.i_softmax_rows(q, scale)
    shape = q.shape
    q2 = q.reshape(-1, shape[-1])
    rows = q2.shape[0]
    br = min(_ism.BLOCK_ROWS, rows)
    q2 = _pad_to(q2, br, 0)
    out = _ism.i_softmax(q2, jnp.asarray(scale, jnp.float32), block_rows=br,
                         interpret=impl == "interpret")
    return out[:rows].reshape(shape)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    impl: Optional[str] = None) -> jax.Array:
    """Fused flash attention. q:(B,S,H,hd), k/v:(B,S,KVH,hd) -> (B,S,H,hd).

    GQA is handled by repeating per-head views into the kernel's flattened
    (B*H, S, hd) layout (views, not materialized copies, on TPU); minimum
    padding to tile multiples per the paper's NUM_PE rule."""
    impl = impl or default_impl()
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if impl == "ref":
        scale = 1.0 / (hd ** 0.5)  # the oracle expects pre-scaled q
        out = jax.vmap(jax.vmap(
            lambda qq, kk, vv: _ref.flash_attention(qq * scale, kk, vv,
                                                    causal),
            in_axes=(1, 1, 1), out_axes=1))(
                q, jnp.repeat(k, h // kvh, axis=2),
                jnp.repeat(v, h // kvh, axis=2))
        return out
    from repro.kernels import flash_attention as _fa

    g = h // kvh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    bq = min(_fa.BQ, _rup(s, 8))
    bk = min(_fa.BK, _rup(s, 8))
    pad = (-s) % bq
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = _fa.flash_attention(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                              kv_len=s, interpret=impl == "interpret")
    out = out[:, :s].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kpos: jax.Array, qpos: jax.Array,
                 active: Optional[jax.Array] = None,
                 window: int = 0, bs: Optional[int] = None,
                 impl: Optional[str] = None) -> jax.Array:
    """Split-KV single-query (decode) attention over a slot KV cache.

    q: (B, H, hd) *pre-scaled* by 1/sqrt(hd) (both impls — unlike
    `flash_attention`, whose kernel scales internally); k/v: (B, Sk, KVH,
    hd); kpos: (B, Sk) int32 absolute positions (2^30 = never-written
    sentinel); qpos: (B,) int32; active: optional (B,) bool slot gate;
    window: sliding-window width (0 = none); bs: KV split length.
    Returns (B, H, hd) in q.dtype.
    """
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.flash_decode(q, k, v, kpos, qpos, active=active,
                                 window=window)
    from repro.kernels import flash_decode as _fd

    b, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bs = bs or min(_fd.BS, _rup(sk, 8))
    qg = q.reshape(b, kvh, g, hd)
    gp = _rup(g, 8)  # group dim is the sublane axis: pad to tile granularity
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    pad = (-sk) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad keys carry the never-written sentinel: masked, not attended
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)),
                       constant_values=_fd.KPOS_SENTINEL)
    act = (jnp.ones((b, 1), jnp.int32) if active is None
           else active.astype(jnp.int32).reshape(b, 1))
    out = _fd.flash_decode(
        qg, k, v, kpos.astype(jnp.int32),
        qpos.astype(jnp.int32).reshape(b, 1), act,
        window=window, bs=bs, interpret=impl == "interpret")
    return out[:, :, :g].reshape(b, h, hd)


def _shard_map_heads(call, mesh, axis, q, arena_and_rest, arena_specs):
    """Dispatch a paged-decode call under shard_map with the kv-head axis
    partitioned over `axis` and the page table / positions replicated.

    `q` is (B, H, hd) with heads laid out kvh-major (ops reshape to
    (B, KVH, G, hd) below), so a contiguous H split is exactly a KV-head
    split — each shard holds whole GQA groups and computes its heads'
    outputs locally; per-head math (online softmax over that head's pages)
    never crosses the axis, which is what keeps the sharded dispatch
    bit-identical to the unsharded one.  This is the SPMD form of the
    paper's scatter-GMI -> per-head kernels -> gather-GMI pipeline stage.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.pipeline import shard_map_compat

    n = mesh.shape[axis]
    b, h, _ = q.shape
    kvh = arena_and_rest[0].shape[2]
    assert h % n == 0 and kvh % n == 0, (
        f"head axes (H={h}, KVH={kvh}) must divide mesh axis "
        f"'{axis}' ({n}); the caller should fall back to the unsharded "
        "dispatch instead")
    return shard_map_compat(
        call, mesh,
        in_specs=(P(None, axis),) + arena_specs,
        out_specs=P(None, axis),
    )(q, *arena_and_rest)


def paged_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                       kpos: jax.Array, page_table: jax.Array,
                       qpos: jax.Array,
                       active: Optional[jax.Array] = None,
                       impl: Optional[str] = None,
                       mesh=None, axis: Optional[str] = None) -> jax.Array:
    """Single-query decode attention over a paged KV arena.

    q: (B, H, hd) *pre-scaled* by 1/sqrt(hd); k/v: (P, ps, KVH, hd) global
    page arenas; kpos: (P, ps) int32 absolute positions (2^30 =
    never-written sentinel); page_table: (B, MAXP) int32 mapping lane b's
    logical page j to an arena page (entries may repeat across lanes —
    radix-shared prefixes; unused entries must name pages whose kpos are
    all sentinel, e.g. the allocator's trash page 0); qpos: (B,) int32;
    active: optional (B,) bool lane gate.  Returns (B, H, hd) in q.dtype.

    Unlike the dense wrapper there is no KV padding to do: pages are the
    tile granularity already.  Sliding windows aren't supported here — the
    serving engine keeps windowed (ring-buffer) caches on the dense slot
    path.

    mesh/axis: dispatch under shard_map with the arena's kv-head dim (and
    q's head dim) partitioned over `axis` and kpos/page_table/qpos/active
    replicated — the plan-sharded serving path (`_shard_map_heads`).
    """
    impl = impl or default_impl()
    if mesh is not None and axis is not None and mesh.shape[axis] > 1:
        from jax.sharding import PartitionSpec as P
        b = q.shape[0]
        act = (jnp.ones((b,), bool) if active is None
               else active.astype(bool))

        def call(qb, kb, vb, kpb, ptb, qpb, ab):
            return paged_flash_decode(qb, kb, vb, kpb, ptb, qpb,
                                      active=ab, impl=impl)

        return _shard_map_heads(
            call, mesh, axis, q, (k, v, kpos, page_table, qpos, act),
            (P(None, None, axis), P(None, None, axis), P(), P(), P(), P()))
    if impl == "ref":
        return _ref.paged_flash_decode(q, k, v, kpos, page_table, qpos,
                                       active=active)
    from repro.kernels import flash_decode as _fd

    b, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    gp = _rup(g, 8)  # group dim is the sublane axis: pad to tile granularity
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    act = (jnp.ones((b, 1), jnp.int32) if active is None
           else active.astype(jnp.int32).reshape(b, 1))
    out = _fd.paged_flash_decode(
        qg, k, v, kpos.astype(jnp.int32), page_table.astype(jnp.int32),
        qpos.astype(jnp.int32).reshape(b, 1), act,
        interpret=impl == "interpret")
    return out[:, :, :g].reshape(b, h, hd)


def paged_flash_decode_q(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         kpos: jax.Array, page_table: jax.Array,
                         qpos: jax.Array,
                         active: Optional[jax.Array] = None,
                         impl: Optional[str] = None,
                         mesh=None, axis: Optional[str] = None) -> jax.Array:
    """Single-query decode attention over a *quantized* (int8) paged arena.

    Same contract as `paged_flash_decode` with k/v int8 and
    k_scale/v_scale: (P, ps, KVH) f32 per-row per-kv-head symmetric scales
    (core/quant.kv_quantize).  Scales live in the arena and are gathered
    through the same page-table indirection as kpos, so radix-shared
    prefix pages dequantize identically for every lane that names them.
    Dequantization happens inside the kernel (VMEM) / oracle (f32), and
    the result is cast back to q.dtype here so both impls return the same
    dtype the unquantized path would.
    """
    impl = impl or default_impl()
    assert k.dtype == jnp.int8 and v.dtype == jnp.int8, (k.dtype, v.dtype)
    if mesh is not None and axis is not None and mesh.shape[axis] > 1:
        from jax.sharding import PartitionSpec as P
        b = q.shape[0]
        act = (jnp.ones((b,), bool) if active is None
               else active.astype(bool))

        def call(qb, kb, vb, ksb, vsb, kpb, ptb, qpb, ab):
            return paged_flash_decode_q(qb, kb, vb, ksb, vsb, kpb, ptb,
                                        qpb, active=ab, impl=impl)

        # scale planes (P, ps, KVH) ride the same kv-head partition as the
        # int8 values they dequantize
        return _shard_map_heads(
            call, mesh, axis, q,
            (k, v, k_scale, v_scale, kpos, page_table, qpos, act),
            (P(None, None, axis), P(None, None, axis), P(None, None, axis),
             P(None, None, axis), P(), P(), P(), P()))
    if impl == "ref":
        out = _ref.paged_flash_decode_q(q, k, v, k_scale, v_scale, kpos,
                                        page_table, qpos, active=active)
        return out.astype(q.dtype)
    from repro.kernels import flash_decode as _fd

    b, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    gp = _rup(g, 8)  # group dim is the sublane axis: pad to tile granularity
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    act = (jnp.ones((b, 1), jnp.int32) if active is None
           else active.astype(jnp.int32).reshape(b, 1))
    out = _fd.paged_flash_decode_q(
        qg, k, v, k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
        kpos.astype(jnp.int32), page_table.astype(jnp.int32),
        qpos.astype(jnp.int32).reshape(b, 1), act,
        interpret=impl == "interpret")
    return out[:, :, :g].reshape(b, h, hd)


def i_layernorm(q8: jax.Array, prep: LNParams, impl: Optional[str] = None):
    """Integer LayerNorm over last axis. Returns (int32 values, s_out)."""
    impl = impl or default_impl()
    if impl == "ref":
        out = _ref.i_layernorm_rows(q8, prep.q_gamma, prep.q_beta, prep.s_gamma)
        return out, prep.s_out
    shape = q8.shape
    q2 = q8.reshape(-1, shape[-1])
    rows = q2.shape[0]
    br = min(_iln.BLOCK_ROWS, rows)
    q2 = _pad_to(q2, br, 0)
    out = _iln.i_layernorm(q2, prep.q_gamma, prep.q_beta, block_rows=br,
                           interpret=impl == "interpret")
    return out[:rows].reshape(shape), prep.s_out
