"""Pallas TPU kernel: integer LayerNorm (I-BERT), row-blocked.

Paper Fig. 10 layers 4 & 6 (LayerNorm modules, Kern_29/Kern_32).  Each grid
step holds a (block_rows, H) int8 tile in VMEM plus the int32 gamma/beta
vectors; mean/var/Newton-isqrt run entirely in integer VREG math.  H is the
model hidden size (<= 8192 for all assigned archs: fits VMEM comfortably,
e.g. 8 x 8192 int32 = 256KB working set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ibert_ops import LN_NORM_SHIFT, _ISQRT_ITERS

BLOCK_ROWS = 8


def _i_sqrt_block(n: jax.Array) -> jax.Array:
    bits = jnp.ceil(jnp.log2(jnp.maximum(n, 1).astype(jnp.float32) + 1.0))
    x0 = jnp.maximum(jnp.exp2(jnp.ceil(bits / 2.0)).astype(jnp.int32), 1)

    def body(_, carry):
        x, done = carry
        nx = (x + n // jnp.maximum(x, 1)) >> 1
        newdone = done | (nx >= x)
        return jnp.where(newdone, x, nx), newdone

    x, _ = jax.lax.fori_loop(0, _ISQRT_ITERS, body,
                             (x0, jnp.zeros(n.shape, dtype=bool)))
    return jnp.where(n == 0, 0, x)


def _kernel(x_ref, g_ref, b_ref, o_ref):
    q = x_ref[...].astype(jnp.int32)
    h = q.shape[-1]
    mean = jnp.sum(q, axis=-1, keepdims=True) // h
    qc = q - mean
    var = jnp.sum(qc * qc, axis=-1, keepdims=True) // h
    std_s = jnp.maximum(_i_sqrt_block(var << 14), 1)
    norm = (qc * (1 << (LN_NORM_SHIFT + 7))) // std_s
    o_ref[...] = (norm * g_ref[...].astype(jnp.int32)
                  + b_ref[...].astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def i_layernorm(q8: jax.Array, q_gamma: jax.Array, q_beta: jax.Array,
                *, block_rows: int = BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    """q8: (R, H) int8-range values; q_gamma/q_beta: (H,) int32. -> (R,H) int32."""
    r, h = q8.shape
    assert r % block_rows == 0, (r, block_rows)
    return pl.pallas_call(
        _kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, h), jnp.int32),
        interpret=interpret,
    )(q8, q_gamma.reshape(1, h), q_beta.reshape(1, h))
