"""Pallas TPU kernel: INT8 x INT8 -> INT32 matmul with fused bias + requant.

This is the paper's Linear module (Matrix-Multiply + Bias Addition + Quant,
§7.1.1) re-tiled for the TPU MXU instead of FPGA DSP tiles:

  * The FPGA design streams the input matrix row-wise through PEs that each
    hold one weight column in BRAM.  On TPU the analogue is: weight tile
    resident in VMEM, input tile streamed HBM->VMEM by the pallas grid, MXU
    consuming 128x128-aligned int8 tiles (int8 matmul is MXU-native).
  * The paper pads only to NUM_PE multiples; we pad only to tile multiples
    (done by ops.py), the same minimum-padding idea.

Grid: (M/bm, N/bn, K/bk), K innermost so the int32 accumulator tile stays
resident in a VMEM scratch across the K loop; bias-add + requantization run
as a fused epilogue on the final K step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# default tiles: a(bm,bk)+b(bk,bn) int8 = 2*64KB, acc(bm,bn) int32 = 64KB
BM, BN, BK = 128, 128, 512


def _kernel(a_ref, b_ref, sa_ref, sb_ref, so_ref, bias_ref, o_ref, acc_ref, *,
            n_k: int, requant: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.int32)
        if requant:
            ratio = sa_ref[0, 0] * sb_ref[0, 0] / so_ref[0, 0]
            x = acc.astype(jnp.float32) * ratio
            q = jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)
            o_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
        else:
            o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "requant", "interpret"),
)
def int8_matmul(a: jax.Array, b: jax.Array, s_a: jax.Array, s_b: jax.Array,
                s_out: Optional[jax.Array] = None,
                bias: Optional[jax.Array] = None,
                *, bm: int = BM, bn: int = BN, bk: int = BK,
                requant: bool = False, interpret: bool = False) -> jax.Array:
    """a:(M,K) int8, b:(K,N) int8 -> (M,N) int32 (or int8 if requant).

    M,K,N must be multiples of the tile sizes (ops.py pads).  s_a/s_b/s_out
    are f32 scalars; bias is int32 (N,) at scale s_a*s_b.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"untiled shape {(m, k, n)} vs tiles {(bm, bn, bk)}")
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    sa2 = s_a.reshape(1, 1).astype(jnp.float32)
    sb2 = s_b.reshape(1, 1).astype(jnp.float32)
    so2 = (s_out if s_out is not None else jnp.float32(1.0)).reshape(1, 1)
    bias2 = bias if bias is not None else None

    out_dtype = jnp.int8 if requant else jnp.int32
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        scalar_spec, scalar_spec, scalar_spec,
        (pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
         if bias2 is not None else None),
    ]
    operands = [a, b, sa2, sb2, so2.astype(jnp.float32)]
    if bias2 is not None:
        operands.append(bias2.reshape(1, n))
    else:
        in_specs = in_specs[:-1]

    kern = functools.partial(_kernel, n_k=n_k, requant=requant)
    if bias2 is None:
        kern = lambda a_r, b_r, sa_r, sb_r, so_r, o_r, acc_r: _kernel(  # noqa: E731
            a_r, b_r, sa_r, sb_r, so_r, None, o_r, acc_r, n_k=n_k, requant=requant)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # int32 accumulator tile resident in VMEM across the K loop
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)
