"""Pallas TPU kernel: integer GELU (I-BERT i-erf polynomial), elementwise.

Paper Fig. 10 layer 5 (Linear+GELU, Kern_30).  Purely elementwise: the
dynamic renormalization shift is derived analytically from the scale (see
ibert_ops.i_gelu), so no cross-tile reduction is needed and tiles can be
streamed at full VPU width.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ibert_ops import _ERF_A, _ERF_B, _ERF_C, _to_i32

BLOCK_ROWS = 64


def _kernel(x_ref, s_ref, o_ref):
    q = x_ref[...]
    scale = s_ref[0, 0]
    s_e = scale / math.sqrt(2.0)
    q_sgn = jnp.sign(q)
    q_b = _to_i32(jnp.floor(-_ERF_B / s_e))
    q_clip = jnp.minimum(jnp.abs(q), q_b)
    q_c = _to_i32(jnp.floor(_ERF_C / (_ERF_A * s_e * s_e)))
    t0 = q_clip - q_b
    q_erf = q_sgn * (t0 * t0 + q_c)
    s_erf = _ERF_A * s_e * s_e
    q_one = _to_i32(jnp.floor(1.0 / s_erf))
    t = q_erf + q_one
    tmax = 2.0 / jnp.abs(s_erf)
    g = jnp.maximum(jnp.ceil(jnp.log2(tmax + 1.0)) - 19.0, 0.0).astype(jnp.int32)
    o_ref[...] = q * (t >> g)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def i_gelu(q: jax.Array, scale: jax.Array, *, block_rows: int = BLOCK_ROWS,
           interpret: bool = False) -> jax.Array:
    """q: (R, C) int32 within ACT_BITS range -> (R, C) int32 (scale per ops.py)."""
    r, c = q.shape
    assert r % block_rows == 0, (r, block_rows)
    return pl.pallas_call(
        _kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        interpret=interpret,
    )(q, scale.reshape(1, 1).astype(jnp.float32))
