from repro.optim.optimizer import (  # noqa: F401
    adamw, adamw8, cosine_schedule, global_norm, make_optimizer, sgdm,
)
from repro.optim.compression import block_quantize, block_dequantize, compressed_psum  # noqa: F401
