"""Optimizers: AdamW, SGD+momentum, and int8-state AdamW (adamw8).

adamw8 stores both Adam moments block-quantized to int8 (bitsandbytes-style
dynamic block scales).  Motivation (DESIGN.md §2): fitting 400B-parameter
FSDP training in v5e HBM — f32 m+v alone is 12.5 GB/chip at 256 chips; int8
states cut that to ~3.2 GB.  This is the paper's integer-arithmetic theme
applied to the optimizer, and a §Perf/memory line item in EXPERIMENTS.md.

API: make_optimizer(name, lr_fn) -> (init_fn, update_fn); states are pytrees
mirroring params, so the Cluster Builder's param specs shard them identically
(ZeRO-style: optimizer state lives wherever its param shard lives).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim.compression import BLOCK

B1, B2, EPS, WD = 0.9, 0.95, 1e-8, 0.1


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float = 1.0):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# -- f32-state AdamW ---------------------------------------------------------


def adamw(lr_fn):
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, wd: float = WD):
        step = state["step"] + 1
        lr = lr_fn(step)
        t = step.astype(jnp.float32)
        bc1 = 1 - B1 ** t
        bc2 = 1 - B2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = B1 * m + (1 - B1) * g
            v = B2 * v + (1 - B2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + EPS)
            u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return init, update


# -- int8-state AdamW --------------------------------------------------------


def _leaf_block(last_dim: int, block: int = BLOCK) -> int:
    """Largest power-of-two block <= BLOCK dividing the last dim."""
    b = block
    while b > 1 and last_dim % b:
        b //= 2
    return b


def _bq(x):
    """Block quantization along the LAST dim, keeping the leaf's shape.

    Param-shaped int8 moments shard exactly like their parameter (ZeRO);
    a flat-striped layout would force a full reshard at every update
    (observed: ~400GB/device replicated dequant buffers on the 400B MoE)."""
    last = x.shape[-1]
    b = _leaf_block(last)
    xb = x.reshape(*x.shape[:-1], last // b, b)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), -1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale.squeeze(-1).astype(jnp.float32)


def _bdq(q, scale):
    last = q.shape[-1]
    nblk = scale.shape[-1]
    b = last // nblk
    xb = q.astype(jnp.float32).reshape(*q.shape[:-1], nblk, b)
    return (xb * scale[..., None]).reshape(q.shape)


def adamw8(lr_fn):
    def init(params):
        def z(p):
            b = _leaf_block(p.shape[-1] if p.ndim else 1)
            sshape = (p.shape[:-1] + (max(p.shape[-1], 1) // b,)
                      if p.ndim else (1,))
            return {"q": jnp.zeros(p.shape if p.ndim else (1,), jnp.int8),
                    "s": jnp.zeros(sshape, jnp.float32)}

        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, wd: float = WD):
        step = state["step"] + 1
        lr = lr_fn(step)
        t = step.astype(jnp.float32)
        bc1 = 1 - B1 ** t
        bc2 = 1 - B2 ** t

        def upd(g, mq, vq, p):
            g = g.astype(jnp.float32)
            shape = p.shape if p.ndim else (1,)
            g = g.reshape(shape)
            m = B1 * _bdq(mq["q"], mq["s"]) + (1 - B1) * g
            v = B2 * _bdq(vq["q"], vq["s"]) + (1 - B2) * g * g
            v = jnp.maximum(v, 0.0)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + EPS)
            u = u + wd * p.astype(jnp.float32).reshape(shape)
            newp = (p.astype(jnp.float32).reshape(shape)
                    - lr * u).astype(p.dtype).reshape(p.shape)
            mq2 = dict(zip(("q", "s"), _bq(m)))
            vq2 = dict(zip(("q", "s"), _bq(v)))
            return newp, mq2, vq2

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        is_blk = lambda x: isinstance(x, dict) and "q" in x  # noqa: E731
        leaves_m = jax.tree.leaves(state["m"], is_leaf=is_blk)
        leaves_v = jax.tree.leaves(state["v"], is_leaf=is_blk)
        outs = [upd(g, m, v, p) for g, m, v, p in
                zip(leaves_g, leaves_m, leaves_v, leaves_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return init, update


def sgdm(lr_fn, momentum: float = 0.9):
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, wd: float = 0.0):
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state["m"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "step": step}

    return init, update


def make_optimizer(name: str, lr_fn) -> Tuple[Callable, Callable]:
    return {"adamw": adamw, "adamw8": adamw8, "sgdm": sgdm}[name](lr_fn)
