"""Gradient compression for the inter-cluster (cross-pod) hop.

The paper's gateway restriction exists to economize the expensive
inter-cluster network (§4).  The training-side analogue on multi-pod TPU is
compressing the gradient all-reduce that crosses the pod (DCN-class) link:
block-wise int8 quantization with error feedback, exchanged as int8 + f32
scales (≈ 4x fewer bytes on the slow link than an f32 ring all-reduce),
decompressed and summed locally.  The int8 theme matches I-BERT's (C4).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

BLOCK = 256


def block_quantize(x: jax.Array, block: int = BLOCK
                   ) -> Tuple[jax.Array, jax.Array, int]:
    """Flatten -> (int8 values, f32 per-block scales, pad)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def block_dequantize(q: jax.Array, scale: jax.Array, pad: int,
                     shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis: str, block: int = BLOCK) -> jax.Array:
    """All-reduce over `axis` exchanging int8 blocks instead of f32.

    all_gather(int8) + local dequant-sum: for axis size N the link carries
    N * size bytes instead of ~2 * 4 * size for an f32 ring — a win for the
    N=2 pod axis this is built for.  Must run inside shard_map.
    """
    q, scale, pad = block_quantize(x, block)
    qg = lax.all_gather(q, axis)  # (N, nblk, block) int8
    sg = lax.all_gather(scale, axis)
    total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape)


def quantize_residual(x: jax.Array, block: int = BLOCK):
    """(compressed, residual) pair for error-feedback accumulation."""
    q, scale, pad = block_quantize(x, block)
    deq = block_dequantize(q, scale, pad, x.shape)
    return (q, scale, pad), x - deq
