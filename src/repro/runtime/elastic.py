"""Elastic re-meshing after capacity loss.

When a pod/slice drops out, training resumes on a smaller mesh: the `data`
axis shrinks (model parallelism is kept intact so the sharded weights still
fit), per-device batch is rebalanced, and the checkpoint is restored with
the new shardings (CheckpointManager.restore(shardings=...) re-places every
leaf).  The paper's cluster-granular restart (§6) maps to exactly this:
lose a cluster, keep the rest serving/training.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax


def elastic_mesh_shape(n_devices: int, model_parallel: int,
                       pod_size: Optional[int] = None
                       ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) mesh that fits n_devices.

    Keeps `model` fixed (weights must stay shardable), shrinks `data`, and
    drops the pod axis if fewer than 2 full pods remain.
    """
    if n_devices % model_parallel != 0:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model={model_parallel}")
    groups = n_devices // model_parallel
    if pod_size and n_devices >= 2 * pod_size and n_devices % pod_size == 0:
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    return (groups, model_parallel), ("data", "model")


def rebalanced_batch(global_batch: int, data_parallel: int) -> int:
    """Per-replica batch after elastic shrink (keeps global batch by
    increasing per-device share when divisible, else grad-accumulates)."""
    if global_batch % data_parallel == 0:
        return global_batch // data_parallel
    # fall back: next divisible global batch below the target
    return max(global_batch // data_parallel, 1)


def accumulation_steps(global_batch: int, data_parallel: int,
                       max_per_device: int) -> int:
    per = -(-global_batch // data_parallel)
    return max(1, -(-per // max_per_device))
