from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureInjector, SimulatedFailure, run_with_recovery,
)
from repro.runtime.stragglers import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import elastic_mesh_shape  # noqa: F401
