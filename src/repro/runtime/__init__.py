"""Runtime package.  Lazy re-exports (PEP 562): fault_tolerance/elastic
pull jax, but stragglers (AdmissionDeadline, StragglerMonitor) is plain
host code the jax-free serving scheduler depends on — importing
`repro.runtime.stragglers` must not drag the accelerator stack in."""

_LAZY = {
    "FailureInjector": "repro.runtime.fault_tolerance",
    "SimulatedFailure": "repro.runtime.fault_tolerance",
    "run_with_recovery": "repro.runtime.fault_tolerance",
    "StragglerMonitor": "repro.runtime.stragglers",
    "elastic_mesh_shape": "repro.runtime.elastic",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
