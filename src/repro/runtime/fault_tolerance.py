"""Failure injection + checkpoint/restart recovery loop.

Paper §6: "When one FPGA fails in a cluster, only the cluster that holds the
failed FPGA needs to be re-configured ... packets sent to this cluster will
be buffered in the cluster input buffer."  At pod scale the analogue is:
detect the failure, restore the last checkpoint (possibly onto a smaller
elastic mesh, see elastic.py), and replay from the buffered data-pipeline
position — which is deterministic here, so replay = reseeking the pipeline.

`run_with_recovery` is the generic driver used by launch/train.py and the
fault-tolerance tests; failures are injected deterministically so tests are
reproducible.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

log = logging.getLogger(__name__)


class SimulatedFailure(RuntimeError):
    """Stands in for a lost TPU slice / preempted pod."""

    def __init__(self, step: int, kind: str = "node_loss"):
        super().__init__(f"simulated {kind} at step {step}")
        self.step = step
        self.kind = kind


@dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: kind}."""

    schedule: Dict[int, str] = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(step, self.schedule[step])


@dataclass
class RecoveryReport:
    restarts: int = 0
    failed_steps: List[int] = field(default_factory=list)
    completed_steps: int = 0
    recovered_from: List[int] = field(default_factory=list)


def run_with_recovery(
    make_state: Callable[[], Any],
    train_steps: Callable[[Any, int, int], Any],
    save: Callable[[int, Any], None],
    restore: Callable[[], Optional[tuple]],
    total_steps: int,
    checkpoint_every: int,
    max_restarts: int = 8,
) -> tuple:
    """Generic restartable loop.

    train_steps(state, start, stop) runs [start, stop) and may raise
    SimulatedFailure (or any RuntimeError); restore() -> (step, state) | None.
    """
    report = RecoveryReport()
    restored = restore()
    if restored is not None:
        start, state = restored
        report.recovered_from.append(start)
    else:
        start, state = 0, make_state()

    step = start
    while step < total_steps:
        stop = min(step + checkpoint_every, total_steps)
        try:
            state = train_steps(state, step, stop)
            step = stop
            save(step, state)
            report.completed_steps = step
        except (SimulatedFailure, RuntimeError) as e:
            report.restarts += 1
            report.failed_steps.append(getattr(e, "step", step))
            if report.restarts > max_restarts:
                raise
            log.warning("failure %s; restoring last checkpoint", e)
            restored = restore()
            if restored is None:
                step, state = 0, make_state()
            else:
                step, state = restored
            report.recovered_from.append(step)
    return state, report
