"""Straggler detection & mitigation.

At 1000+ nodes, tail-latency steps (one slow host, a flaky link) dominate
synchronous training.  The monitor keeps an EMA of step times, flags steps
slower than `threshold` x EMA, and drives two mitigations:

  * skip-and-resync: if a *data host* is the straggler, its shard for this
    step is dropped and the gradient is rescaled (bounded staleness — the
    SPMD equivalent of the paper's per-cluster input buffering riding out a
    slow cluster).
  * admission deadline (serving): the continuous-batching engine admits
    requests into freed slots between decode steps; `AdmissionDeadline`
    bounds how long an arrived request may be jumped by warm-bucket peers
    before it is force-admitted FIFO.  (The legacy wave engine used the
    same deadline to launch partial waves.)

On this CPU container the "slow node" is injected by tests via a delay hook.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    threshold: float = 2.5  # x EMA counts as straggler
    ema_decay: float = 0.9
    warmup_steps: int = 3

    _ema: Optional[float] = None
    _n: int = 0
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, duration: float) -> bool:
        """Record a step time; returns True if flagged as a straggler."""
        self._n += 1
        if self._ema is None:
            self._ema = duration
            return False
        flagged = (self._n > self.warmup_steps
                   and duration > self.threshold * self._ema)
        if flagged:
            self.events.append({"step": step, "duration": duration,
                                "ema": self._ema})
        else:
            # stragglers don't poison the EMA
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * duration)
        return flagged

    @property
    def ema(self) -> Optional[float]:
        return self._ema


@dataclass
class AdmissionDeadline:
    """Serving admission deadline (paper §8.2 line-rate ingress analogue).

    A request that has waited longer than `deadline_s` since arrival takes
    absolute priority in core/packing.AdmissionPolicy — bucket-warmth
    preferences may reorder younger requests only.  deadline_s <= 0 disables
    reordering entirely (strict FIFO admission).
    """

    deadline_s: float = 0.05

    def overdue(self, wait_s: float) -> bool:
        return wait_s >= self.deadline_s


def timed(monitor: StragglerMonitor, step: int, fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    flagged = monitor.observe(step, time.perf_counter() - t0)
    return out, flagged
