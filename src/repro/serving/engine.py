"""Serving engines: the composition root over the three serving layers.

serving/scheduler.py holds host-side policy only (admission ordering, the
decode-horizon ladder, preemption choice, stream reconciliation — no
jax); serving/executor.py holds every jitted program plus plan placement
(the `mode="serve"` kv-head-sharded paged path and the
`mode="serve_pipeline"` stage-streaming decode); serving/kv_manager.py
owns paged-KV memory (page pool, radix prefix cache, page tables).  This
module wires the three together behind the old monolith's public API
(semantics in docs/serving.md).
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.packing import bucket_len
from repro.models.transformer import Model
from repro.runtime.stragglers import StragglerMonitor
from repro.serving.executor import PAD_TOKEN, Executor
from repro.serving.kv_manager import (KVManager, kv_page_bytes,
                                      num_pages_for_hbm, paged_eligible)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "WaveEngine", "ServingEngine",
           "Request", "EngineBase", "PAD_TOKEN", "kv_page_bytes",
           "num_pages_for_hbm"]


class EngineBase:
    """Shared composition: scheduler + executor, stats, prefill plumbing."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 buckets=(32, 64, 128, 256), greedy: bool = True,
                 deadline_s: float = 0.05, plan=None,
                 max_decode_len: int = 64, decode_horizon: int = 8,
                 monitor: Optional[StragglerMonitor] = None,
                 quant_weights: bool = False):
        self.model = model
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets))
        self.greedy = greedy
        self.plan = plan
        self.monitor = monitor
        self.quant_weights = bool(quant_weights)
        self.paged = False  # ContinuousBatchingEngine may flip this
        # slot rows: prompt KV + decode headroom, fixed per engine
        self.cache_len = bucket_len(max(self.buckets), self.buckets,
                                    lane=8) + max_decode_len
        self.decode_horizon = decode_horizon
        self.sched = Scheduler(self.buckets, deadline_s, decode_horizon,
                               max_batch)
        self.executor = Executor(model, params, plan=plan,
                                 quant_weights=quant_weights,
                                 max_batch=max_batch,
                                 cache_len=self.cache_len,
                                 buckets=self.buckets)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "device_syncs": 0}

    params = property(lambda self: self.executor.params)
    policy = property(lambda self: self.sched.policy)

    def submit(self, req: Request) -> None:
        need = self.policy.bucket_of(len(req.prompt)) + req.max_new_tokens
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: bucket+budget {need} exceeds slot "
                f"cache_len {self.cache_len} (raise max_decode_len)")
        if self.paged and self.kv.pages_for(need) > self.kv.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: needs {self.kv.pages_for(need)} pages,"
                f" pool has {self.kv.num_pages - 1} (raise num_pages)")
        self.sched.enqueue(req)

    def _prefill(self, prompts, batch: int, bucket_cache: bool = False):
        self.stats["prefill_tokens"] += int(sum(len(p) for p in prompts))
        return self.executor.prefill_prompts(prompts, batch,
                                             bucket_cache=bucket_cache)

    def _greedy_next(self, logits) -> np.ndarray:
        self.stats["device_syncs"] += 1
        return np.asarray(jnp.argmax(logits, -1), np.int32)


class ContinuousBatchingEngine(EngineBase):
    """Slot-asynchronous scheduler: admit into freed slots between steps.

    ``paged`` (default "auto") swaps dense slot rows for the page arena +
    radix prefix cache; ``kv_dtype="int8"`` quantizes the arena;
    ``quant_weights=True`` serves W8A8 — all three compose with a
    ClusterPlan, whose serve mode shards the arena's kv-head dim across
    the mesh (docs/serving.md §sharded serving)."""

    def __init__(self, *args, paged="auto", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_hit_suffix: Optional[int] = None,
                 kv_dtype: str = "bf16", **kw):
        super().__init__(*args, **kw)
        self.stats.update(admitted=0, completed=0, prefills=0,
                          active_lane_steps=0)
        self._slot_caches = None
        eligible = paged_eligible(self.model.cfg, self.plan)
        if paged == "auto":
            paged = eligible
        elif paged and not eligible:
            raise ValueError(
                "paged KV needs an all-attention, unwindowed, causal model "
                "(recurrent state and ring buffers have no paged analogue) "
                "under no plan or a mode='serve' plan (serve_pipeline "
                "streams the dense slot path)")
        self.paged = bool(paged)
        assert kv_dtype in ("bf16", "int8"), kv_dtype
        if kv_dtype == "int8" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' needs the paged KV pool (quantized dense "
                "slot rows are not implemented); this model/config fell "
                "back to dense slots")
        self.kv_dtype = kv_dtype
        self.kv: Optional[KVManager] = None
        if self.paged:
            self.page_size = page_size
            # whole-page capacity: gathered paged layout == dense slot row
            self.cache_len = -(-self.cache_len // page_size) * page_size
            self.executor.cache_len = self.cache_len
            self.max_pages = self.cache_len // page_size
            if num_pages is None:  # default: dense table capacity + trash
                num_pages = self.max_batch * self.max_pages + 1
            self.kv = KVManager(num_pages, page_size, self.max_batch,
                                self.max_pages)
            self.max_hit_suffix = (max(self.buckets)
                                   if max_hit_suffix is None
                                   else max_hit_suffix)
            self._ladder_warm = False
            self.stats.update(prefix_hits=0, prefix_hit_tokens=0,
                              preemptions=0, pages_in_use=0, pages_peak=0)

    pool = property(lambda self: self.kv.pool)
    prefix_cache = property(lambda self: self.kv.prefix_cache)
    _lane_pages = property(lambda self: self.kv._lane_pages)

    def kv_page_bytes(self) -> int:
        """HBM bytes one arena page costs at this engine's kv_dtype."""
        return kv_page_bytes(self.model.cfg, self.page_size, self.kv_dtype)

    def _admit_dense(self, r: Request, sl: int, st) -> bool:
        """Batch-1 prefill + insert into slot `sl`; TTFT paid here."""
        logits, small = self._prefill([r.prompt], 1, bucket_cache=True)
        st["caches"] = self.executor.insert(st["caches"], small, sl)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        self._first_token(r, int(self._greedy_next(logits)[0]))
        if not r.done:
            self.executor.admit_lane(st, sl, r.tokens_out[-1], r.eos_id,
                                     r.remaining())
        return True

    def _admit_paged(self, r: Request, sl: int, st) -> bool:
        """Radix hit -> reuse shared pages, suffix rides the forced-token
        queue; cold -> bucket prefill scattered into owned pages + prompt
        registered.  False = pool can't cover it (nothing held)."""
        prompt = r.effective_prompt()
        grant = self.kv.admit(prompt, r.remaining(), self.max_hit_suffix)
        if grant is None:
            return False
        self.stats["admitted"] += 1
        if grant.hit_len:
            suffix = prompt[grant.hit_len:]
            self.executor.admit_hit(st, sl, grant.pt_row, grant.hit_len,
                                    grant.reset)
            self.executor.admit_lane_paged(st, sl, int(suffix[0]), r.eos_id,
                                           r.remaining(), suffix[1:],
                                           len(suffix) - 1)
            self.sched.lane_forced[sl] = len(suffix) - 1
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += int(grant.hit_len)
            r.t_admitted = time.perf_counter()
        else:
            logits, small = self._prefill([prompt], 1, bucket_cache=True)
            bucket = bucket_len(len(prompt), self.buckets, lane=8)
            n_wp = min(self.kv.pages_for(bucket), len(grant.pages))
            self.executor.admit_cold(
                st, sl, small, grant.pt_row, len(prompt), grant.reset,
                np.asarray(grant.pages[:n_wp], np.int32), bucket)
            self.stats["prefills"] += 1
            self.kv.register_prefix(prompt, grant.pages)
            self._first_token(r, int(self._greedy_next(logits)[0]))
            self.sched.lane_forced[sl] = 0
            if not r.done:
                self.executor.admit_lane_paged(
                    st, sl, r.tokens_out[-1], r.eos_id, r.remaining(),
                    np.zeros((0,), np.int32), 0)
        self.kv.commit(sl, grant)
        self.stats["pages_in_use"] = self.kv.pages_in_use
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self.kv.pages_in_use)
        return True

    @staticmethod
    def _first_token(r: Request, tok: int) -> None:
        t_now = time.perf_counter()
        r.t_admitted = t_now
        r.append_token(tok, t_now)

    def _release(self, sl: int) -> None:
        self.kv.release(sl)
        self.sched.lane_forced[sl] = 0
        self.stats["pages_in_use"] = self.kv.pages_in_use

    def _preempt(self, slots, pending, st) -> None:
        """Evict the lane with the most work left; greedy decode is
        deterministic, so the re-queued victim (usually a prefix hit on
        its own pages) continues exactly where it stopped."""
        sl = self.sched.victim(slots)
        if sl is None:
            return
        victim, slots[sl] = slots[sl], None
        self.executor.park_lane(st, sl)
        self._release(sl)
        pending.append(victim)
        self.stats["preemptions"] += 1

    def _reconcile(self, toks, slots, done, n: int, t_step: float) -> None:
        block = np.asarray(toks)  # the only per-dispatch device sync
        if self.monitor is not None:
            self.monitor.observe(self.stats["decode_steps"] + n,
                                 (time.perf_counter() - t_step) / n)
        self.sched.reconcile(block, slots, done, n, self.stats,
                             time.perf_counter(), self.paged,
                             self._release if self.paged else None)

    def run(self) -> List[Request]:
        """Serve until queue + slots drain; returns requests sorted by rid.
        Admission honours `Request.t_arrival` (seconds after this call)."""
        if self._slot_caches is None:
            self._slot_caches = self.executor.init_caches(
                self.paged, *((self.page_size, self.kv.num_pages,
                               self.max_pages, self.kv_dtype)
                              if self.paged else ()))
        st = self.executor.fresh_state(self._slot_caches, self.paged)
        # programs donate the caches: drop the handle (abnormal-exit safety)
        self._slot_caches = None
        if self.paged and not self._ladder_warm:
            self.executor.warm_ladder(st, self.sched.horizons)
            self._ladder_warm = True
        done: List[Request] = []
        pending = self.sched.take_queue()
        slots: List[Optional[Request]] = [None] * self.max_batch
        admit = self._admit_paged if self.paged else self._admit_dense
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)

        while pending or any(r is not None for r in slots):
            now = time.perf_counter() - t0
            free = [i for i, r in enumerate(slots) if r is None]
            admitted, starved = self.sched.admission_cycle(
                pending, free, now, self.executor.warm_buckets,
                lambda r, sl: admit(r, sl, st))
            for r, sl in admitted:
                pending.remove(r)
                if r.done:  # budget of 1 / instant EOS at admission
                    done.append(r)
                    if self.paged:
                        self._release(sl)
                    free.insert(0, sl)
                    self.stats["completed"] += 1
                else:
                    slots[sl] = r
            if self.sched.should_preempt(starved, now):
                self._preempt(slots, pending, st)
            if not any(r is not None for r in slots):
                self.sched.idle_wait(pending, starved,
                                     time.perf_counter() - t0)
                continue

            n = self.sched.pick_horizon(bool(pending),
                                        self.sched.lane_remaining(slots))
            t_step = time.perf_counter()
            toks = self.executor.decode(st, n, self.paged)
            self._reconcile(toks, slots, done, n, t_step)

        if self.paged:
            self.kv.assert_drained()
        self._slot_caches = st["caches"]
        return sorted(done, key=lambda r: r.rid)


def __getattr__(name):  # PEP 562: WaveEngine (serving/wave.py) subclasses
    if name == "WaveEngine":  # EngineBase — lazy both ways, no import cycle
        from repro.serving.wave import WaveEngine
        return WaveEngine
    raise AttributeError(name)


ServingEngine = ContinuousBatchingEngine
