"""Serving stack: slot-based continuous batching driven by the Cluster plan.

The paper's deployment is a spatial pipeline fed at line rate (§8.2):
requests stream through the 6-FPGA encoder cluster continuously, never
waiting for a "wave" to fill.  The engine mirrors that with *slots*:

  * a persistent KV cache with `max_batch` slot rows, allocated once per
    (slot, cache_len) shape and sharded by the Cluster-Builder serve-mode
    cache specs (`build_plan(..., mode="serve")`);
  * prefill-on-admission: a freed slot is refilled between decode steps by
    a batch-1 bucketed prefill whose cache is written into the slot row via
    a jitted `insert_prefill_cache` — the rest of the batch keeps decoding,
    nothing is torn down;
  * an admission policy (core/packing.AdmissionPolicy) that orders waiting
    requests by deadline overdue-ness (runtime/stragglers.AdmissionDeadline)
    then bucket warmth, so steady state never stalls on a prefill compile;
  * plan-aware execution: with a `ClusterPlan`, params and the slot cache
    are placed with `jax.device_put` under the plan's `NamedSharding`s and
    prefill/decode are jitted with `in_shardings`/`out_shardings` — the
    engine is the runtime consumer of the Cluster Builder's serve plan.

Decode runs on a *horizon*: each dispatch is a fused on-device loop
(`Model.decode_steps` — decode, greedy argmax, feed back, EOS/budget lane
masking, all under one jit) of up to `decode_horizon` steps, and the host
fetches one (n, B) int32 token block instead of one (B, V) logits array per
token.  The horizon is picked adaptively from admission pressure: with
waiting requests it stops at the next predicted completion so a slot frees
at the earliest boundary; with a drained queue it runs long.  Admissions
and completions are reconciled only at horizon boundaries; between them
the decode state (current token, active lanes, budgets) never leaves the
device.  `decode_horizon=1` reproduces the one-dispatch-per-token
scheduler and is the measured baseline in `benchmarks/run.py serve_cb`.

With ``paged=True`` (auto-enabled for all-attention models) the dense
per-slot rows give way to a *paged KV pool*: a global page arena addressed
through per-lane page tables, a free-list allocator and a radix prefix
cache (core/packing), prefix-hit admissions that skip prefill by ingesting
the un-hit suffix through the decode loop's forced-token queue, and
page-aware admission with LRU prefix eviction and preempt-to-free
(docs/serving.md §paged KV).

`WaveEngine` keeps the seed's batch-synchronous scheduler (one batched
prefill, decode to the slowest request) as the measured baseline for the
`benchmarks/run.py serve_cb` comparison; its inner loop rides the same
fused horizon programs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.packing import AdmissionPolicy, bucket_len
from repro.models.transformer import Model
from repro.runtime.stragglers import AdmissionDeadline, StragglerMonitor

PAD_TOKEN = 0  # fed for finished/free slot rows; their logits are never read


def kv_page_bytes(cfg, page_size: int, kv_dtype: str) -> int:
    """HBM bytes one KV arena page costs across the whole layer stack —
    the unit for equal-HBM pool sizing (docs/perf.md §int8 pages).

    bf16: 2 (k+v) * KVH * hd elements at 2 B per cache row; int8: the same
    elements at 1 B plus 2 * KVH f32 scales per row, i.e. (hd+4)/(2*hd) of
    the bf16 bytes — a fixed budget holds ~2x the pages at hd=64.
    """
    per_row = 2 * cfg.n_kv_heads * cfg.head_dim  # k+v elements
    if kv_dtype == "int8":
        row_bytes = per_row + 2 * cfg.n_kv_heads * 4  # values + f32 scales
    else:
        row_bytes = per_row * 2
    return cfg.n_layers * page_size * row_bytes


@dataclass(eq=False)  # identity equality: rid is caller-chosen, prompt is a
class Request:        # numpy array (== would be ambiguous), requests mutate
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    t_arrival: float = 0.0  # seconds after engine start (Poisson streams)
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    def append_token(self, tok: int, now: float) -> None:
        assert not self.done, \
            f"request {self.rid}: token appended after done"
        if not self.tokens_out:
            self.t_first_token = now
        self.tokens_out.append(int(tok))
        if tok == self.eos_id or len(self.tokens_out) >= self.max_new_tokens:
            self.done = True
            self.t_done = now


class EngineBase:
    """Shared plumbing: plan placement, jit caches, bucketed prefill."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 buckets=(32, 64, 128, 256), greedy: bool = True,
                 deadline_s: float = 0.05, plan=None,
                 max_decode_len: int = 64,
                 decode_horizon: int = 8,
                 monitor: Optional[StragglerMonitor] = None,
                 quant_weights: bool = False):
        self.model = model
        # int8 weight path (models/quantized.py): the decode-step
        # projections/MLP run W8A8 through dense()'s quantized dispatch —
        # with kv_dtype="int8" on top the whole decode loop is
        # integer-dominant, the paper's I-BERT datapath at serving scale
        self.quant_weights = bool(quant_weights)
        if self.quant_weights:
            if plan is not None:
                raise ValueError(
                    "quant_weights does not compose with a ClusterPlan yet:"
                    " plan.param_specs are derived from the bf16 leaf tree")
            from repro.models.quantized import quantize_params_for_serving
            params = quantize_params_for_serving(params)
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets))
        self.greedy = greedy
        self.plan = plan
        self.monitor = monitor
        self.policy = AdmissionPolicy(
            buckets=self.buckets, lane=8,
            deadline=AdmissionDeadline(deadline_s))
        # slot rows hold prompt KV + decode headroom; fixed so the decode
        # program compiles exactly once per engine
        self.cache_len = bucket_len(max(self.buckets), self.buckets,
                                    lane=8) + max_decode_len
        # decode-horizon ladder: each fused dispatch runs up to
        # `decode_horizon` on-device decode steps (Model.decode_steps) and
        # ships one (n, B) token block back; powers of two bound the number
        # of compiled horizon programs.  decode_horizon=1 is the measured
        # one-dispatch-per-token baseline (docs/perf.md).
        assert decode_horizon >= 1
        self.decode_horizon = decode_horizon
        self.paged = False  # ContinuousBatchingEngine may flip this
        self._horizons = [h for h in (1, 2, 4, 8, 16, 32, 64, 128)
                          if h <= decode_horizon] or [1]
        self._queue: List[Request] = []
        self._jit_prefill: Dict = {}
        self._jit_decode_steps: Dict[int, Callable] = {}
        self._jit_insert: Optional[Callable] = None
        self._jit_admit_lane: Optional[Callable] = None
        # decode_steps: on-device scan steps; decode_dispatches: fused jit
        # calls; device_syncs: host<->device round-trips (token-block and
        # first-token fetches) — the quantity the horizon amortizes
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "device_syncs": 0}

        self._param_shardings = None
        self._cache_shardings = None
        self._rep = None
        if plan is not None:
            if plan.param_specs is None:
                plan.param_specs = plan.specs_for_params(
                    jax.eval_shape(lambda: params))
            self._param_shardings = jax.tree.map(plan.sharding,
                                                 plan.param_specs)
            self._rep = plan.sharding(P())
            params = jax.device_put(params, self._param_shardings)
        self.params = params

    # -- public ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = self.policy.bucket_of(len(req.prompt)) + req.max_new_tokens
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: bucket+budget {need} exceeds slot "
                f"cache_len {self.cache_len} (raise max_decode_len)")
        if self.paged and self.pool.pages_for(need) > self.pool.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.pages_for(need)} pages,"
                f" pool has {self.pool.num_pages - 1} (raise num_pages)")
        req.t_enqueue = time.perf_counter()
        self._queue.append(req)

    def run(self) -> List[Request]:
        raise NotImplementedError

    # -- jitted programs ------------------------------------------------------

    def _prefill_fn(self, bucket: int, batch: int, cache_slots: int):
        key = (bucket, batch, cache_slots)
        if key not in self._jit_prefill:
            model = self.model

            def fn(params, tokens, positions, lengths):
                caches = model.init_cache(batch, cache_slots)
                logits, caches = model.prefill(
                    params, caches, tokens=tokens, positions=positions,
                    last_idx=lengths - 1)
                return logits, caches

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._param_shardings, self._rep,
                                      self._rep, self._rep)
            self._jit_prefill[key] = jax.jit(fn, **kw)
        return self._jit_prefill[key]

    def _decode_steps_fn(self, n: int):
        """Fused n-step decode program (compiled once per horizon length;
        jax.jit re-specializes per batch shape for the wave engine's
        variable waves).  The paged variant threads the forced-token queue
        (prefix-hit suffix ingest) through the same fused loop."""
        if n not in self._jit_decode_steps:
            model = self.model
            if self.paged:

                def pfn(params, caches, token, active, eos, budget,
                        forced, flen, fptr):
                    return model.decode_steps(
                        params, caches, token, active, n, eos_id=eos,
                        budget=budget, pad_token=PAD_TOKEN, forced=forced,
                        forced_len=flen, forced_ptr=fptr)

                self._jit_decode_steps[n] = jax.jit(pfn, donate_argnums=(1,))
                return self._jit_decode_steps[n]

            def fn(params, caches, token, active, eos, budget):
                return model.decode_steps(params, caches, token, active, n,
                                          eos_id=eos, budget=budget,
                                          pad_token=PAD_TOKEN)

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._param_shardings,
                                      self._cache_shardings, self._rep,
                                      self._rep, self._rep, self._rep)
                kw["out_shardings"] = (self._rep, self._rep, self._rep,
                                       self._rep, self._cache_shardings)
            self._jit_decode_steps[n] = jax.jit(fn, donate_argnums=(1,),
                                                **kw)
        return self._jit_decode_steps[n]

    def _admit_lane_fn(self):
        """One fused update of the device decode state for an admission
        (four eager .at[].set dispatches cost ~4x this on small hosts)."""
        if self._jit_admit_lane is None:

            def fn(cur, active, eos, budget, sl, tok, eos_id, bud):
                return (cur.at[sl].set(tok), active.at[sl].set(True),
                        eos.at[sl].set(eos_id), budget.at[sl].set(bud))

            self._jit_admit_lane = jax.jit(fn, donate_argnums=(0, 1, 2, 3))
        return self._jit_admit_lane

    def _pick_horizon(self, waiting: bool, remaining: List[int]) -> int:
        """Adaptive decode horizon from admission pressure.

        With `waiting` requests, aim for the next *predicted* completion
        (min remaining budget) so a slot frees — and is refilled — at the
        earliest useful horizon boundary, floored at 4 steps so dispatch
        overhead stays amortized (a completion can overshoot by at most 3
        masked slot-steps); with a drained queue run up to the longest
        remaining budget.  EOS can still end a lane mid-horizon; those
        lanes decode masked until the boundary (wasted slot-steps, never
        wrong tokens)."""
        if waiting:
            target = max(min(remaining), min(4, self.decode_horizon))
        else:
            target = max(remaining)
        n = 1
        for h in self._horizons:
            if h <= max(1, target):
                n = h
        return n

    def _append_block(self, block: np.ndarray, requests, now: float) -> None:
        """Reconcile one fetched (n, B) token block into request streams.

        -1 marks a step at which the lane emitted nothing: a free slot, a
        lane that early-exited on device after EOS/budget (-1 *suffix*), or
        a prefix-hit lane still ingesting its prompt suffix through the
        forced-token queue (-1 *prefix*) — so -1 entries are skipped, not
        treated as end-of-block.  Device-side masking mirrors
        `Request.append_token`'s done rule, so the host appends every
        non-negative token until its own done flag flips; nothing real can
        follow a lane's device-side exit."""
        for i, r in enumerate(requests):
            if r is None or r.done:
                continue
            for tok in block[:, i]:
                if tok < 0:
                    continue
                r.append_token(int(tok), now)
                if r.done:
                    break

    def _prefill_batch(self, wave: List[Request], batch: int,
                       bucket_cache: bool = False):
        """Bucketed left-aligned batched prefill; returns (logits, caches).

        bucket_cache=True writes a bucket-sized cache (the slot engine's
        admission path: `insert_prefill_cache` pads it up to the slot row);
        otherwise the cache has the full cache_len the wave engine decodes
        into directly.
        """
        return self._prefill_prompts([r.prompt for r in wave], batch,
                                     bucket_cache=bucket_cache)

    def _prefill_prompts(self, prompts: List[np.ndarray], batch: int,
                         bucket_cache: bool = False):
        """`_prefill_batch` over raw token arrays (the paged engine
        prefills *effective* prompts — original prompt + tokens already
        generated before a preemption — which belong to no Request)."""
        maxlen = max(len(p) for p in prompts)
        bucket = bucket_len(maxlen, self.buckets, lane=8)
        cache_slots = bucket if bucket_cache else self.cache_len
        toks = np.zeros((batch, bucket), np.int32)
        # pad positions = 2^30 so the causal mask can never attend to them
        # (and cache slot i == position i for decode)
        pos = np.full((batch, bucket), 2 ** 30, np.int32)
        lengths = np.ones((batch,), np.int32)
        for i, p in enumerate(prompts):
            n = len(p)
            toks[i, :n] = p
            pos[i, :n] = np.arange(n)
            lengths[i] = n
        self.stats["prefill_tokens"] += int(sum(len(p) for p in prompts))
        return self._prefill_fn(bucket, batch, cache_slots)(
            self.params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(lengths))

    def _greedy_next(self, logits) -> np.ndarray:
        self.stats["device_syncs"] += 1
        return np.asarray(jnp.argmax(logits, -1), np.int32)


class ContinuousBatchingEngine(EngineBase):
    """Slot-asynchronous scheduler: admit into freed slots between steps.

    With ``paged=True`` (the default wherever it applies: all-attention
    models, no sliding window, no ClusterPlan) the per-slot dense KV rows
    are replaced by a global page arena (`core/packing.PagePool`) addressed
    through per-lane page tables, plus a radix prefix cache
    (`core/packing.RadixPrefixCache`): requests sharing a prompt prefix
    reuse its KV pages copy-free and skip prefill for the covered
    positions — the un-hit suffix is ingested through the fused decode
    loop's forced-token queue, so a hit admission costs zero prefill
    dispatches.  Admission is page-aware (admit while pages are available,
    evict cached prefixes LRU under pressure, preempt-to-free as the last
    resort) and `stats` gains prefix_hits / prefix_hit_tokens /
    pages_in_use / pages_peak / preemptions / active_lane_steps.

    ``kv_dtype="int8"`` stores the arena quantized (int8 k/v + per-row
    f32 scale planes, core/quant.kv_quantize): ~half the HBM per resident
    token, so an equal byte budget holds ~2x the pages — size pools
    across dtypes with the module-level `kv_page_bytes`.  Decode
    runs the `paged_flash_decode_q` kernel (in-VMEM dequant); prefix
    pages share scales by construction (they live in the arena), so hit
    admissions stay bit-identical to cold prefills.  Greedy streams match
    bf16 to >=99% on confident models (docs/serving.md §kv_dtype for the
    caveats); combine with ``quant_weights=True`` for an
    integer-dominant decode loop.
    """

    def __init__(self, *args, paged="auto", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_hit_suffix: Optional[int] = None,
                 kv_dtype: str = "bf16", **kw):
        super().__init__(*args, **kw)
        # active_lane_steps / decode_steps = sustained concurrency (mean
        # occupied lanes per decode step) — the capacity metric the paged
        # pool is meant to raise at fixed HBM
        self.stats.update(admitted=0, completed=0, prefills=0,
                          active_lane_steps=0)
        self._slot_caches = None
        from repro.core.packing import PagePool, RadixPrefixCache
        from repro.models.transformer import layer_plan
        cfg = self.model.cfg
        _, _, kinds = layer_plan(cfg)
        eligible = (all(k == "attn" for k in kinds)
                    and not cfg.local_window and cfg.causal
                    and self.plan is None)
        if paged == "auto":
            paged = eligible
        elif paged and not eligible:
            raise ValueError(
                "paged KV needs an all-attention, unwindowed, causal model "
                "without a ClusterPlan (recurrent state and ring buffers "
                "have no paged analogue; plan sharding covers slot tables)")
        self.paged = bool(paged)
        assert kv_dtype in ("bf16", "int8"), kv_dtype
        if kv_dtype == "int8" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' needs the paged KV pool (quantized dense "
                "slot rows are not implemented); this model/config fell "
                "back to dense slots")
        self.kv_dtype = kv_dtype
        if self.paged:
            self.page_size = page_size
            # round the per-lane logical capacity up to whole pages: the
            # gathered paged layout then matches a dense slot row exactly
            # (position p at logical row p), which is what makes paged and
            # dense token streams directly comparable
            self.cache_len = -(-self.cache_len // page_size) * page_size
            self.max_pages = self.cache_len // page_size
            if num_pages is None:
                # default pool = the dense slot table's capacity (+ trash
                # page): paging is then never the binding constraint.  Size
                # num_pages down — or max_batch up at fixed pool bytes — to
                # trade worst-case headroom for real concurrency
                # (docs/perf.md has the HBM inventory).
                num_pages = self.max_batch * self.max_pages + 1
            self.pool = PagePool(num_pages, page_size)
            self.prefix_cache = RadixPrefixCache(self.pool)
            # a hit whose un-hit suffix exceeds this re-ingests too many
            # tokens through the decode loop; one dense prefill is cheaper
            self.max_hit_suffix = (max(self.buckets)
                                   if max_hit_suffix is None
                                   else max_hit_suffix)
            self._lane_pages: List[Optional[List[int]]] = \
                [None] * self.max_batch
            self._lane_forced = [0] * self.max_batch
            self._jit_admit_cold: Dict = {}
            self._jit_admit_hit = None
            self._jit_admit_lane_paged = None
            self._jit_park_lane = None
            self._ladder_warm = False
            self.stats.update(prefix_hits=0, prefix_hit_tokens=0,
                              preemptions=0, pages_in_use=0, pages_peak=0)

    # -- internals ------------------------------------------------------------

    def kv_page_bytes(self) -> int:
        """HBM bytes one arena page costs at this engine's kv_dtype (the
        module-level `kv_page_bytes` bound to this engine's config)."""
        return kv_page_bytes(self.model.cfg, self.page_size, self.kv_dtype)

    def _init_slot_caches(self):
        if self.paged:
            return self.model.init_paged_cache(
                self.max_batch, self.pool.num_pages, self.page_size,
                self.max_pages, kv_dtype=self.kv_dtype)
        caches = self.model.init_cache(self.max_batch, self.cache_len)
        if self.plan is not None:
            specs = self.plan.specs_for_caches(
                jax.eval_shape(lambda: caches), batch=self.max_batch,
                slot_table=True)
            self._cache_shardings = jax.tree.map(self.plan.sharding, specs)
            caches = jax.device_put(caches, self._cache_shardings)
        return caches

    def _insert_fn(self):
        if self._jit_insert is None:
            model = self.model

            def fn(big, small, slot):
                return model.insert_prefill_cache(big, small, slot)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._cache_shardings
            self._jit_insert = jax.jit(fn, donate_argnums=(0,), **kw)
        return self._jit_insert

    def _admit(self, req: Request, slot: int, caches):
        """Batch-1 prefill + jitted insert into `slot`; returns (caches, tok).

        The first token comes straight from the prefill logits, so TTFT is
        paid at admission, not at the next decode step.
        """
        logits, small = self._prefill_batch([req], 1, bucket_cache=True)
        caches = self._insert_fn()(caches, small, slot)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        return caches, int(self._greedy_next(logits)[0])

    # -- paged internals ------------------------------------------------------

    def _admit_cold_fn(self, bucket: int, n_wp: int):
        key = (bucket, n_wp)
        if key not in self._jit_admit_cold:
            model = self.model

            def fn(big, small, slot, pt_row, pos0, reset, wp):
                return model.admit_lane_cache(big, slot, pt_row, pos0,
                                              reset, small=small,
                                              write_pages=wp)

            self._jit_admit_cold[key] = jax.jit(fn, donate_argnums=(0,))
        return self._jit_admit_cold[key]

    def _admit_hit_fn(self):
        if self._jit_admit_hit is None:
            model = self.model

            def fn(big, slot, pt_row, pos0, reset):
                return model.admit_lane_cache(big, slot, pt_row, pos0, reset)

            self._jit_admit_hit = jax.jit(fn, donate_argnums=(0,))
        return self._jit_admit_hit

    def _admit_lane_paged_fn(self):
        """Fused device-state update for a paged admission: lane decode
        state plus the forced-token (suffix-ingest) queue row."""
        if self._jit_admit_lane_paged is None:

            def fn(cur, active, eos, budget, forced, flen, fptr, sl, tok,
                   eos_id, bud, frow, fl):
                return (cur.at[sl].set(tok), active.at[sl].set(True),
                        eos.at[sl].set(eos_id), budget.at[sl].set(bud),
                        forced.at[sl].set(frow), flen.at[sl].set(fl),
                        fptr.at[sl].set(0))

            self._jit_admit_lane_paged = jax.jit(
                fn, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
        return self._jit_admit_lane_paged

    def _park_lane_fn(self):
        """Deactivate a lane on device (preemption): masked writes go to
        the trash page from the next step on."""
        if self._jit_park_lane is None:

            def fn(cur, active, sl):
                return cur.at[sl].set(PAD_TOKEN), active.at[sl].set(False)

            self._jit_park_lane = jax.jit(fn, donate_argnums=(0, 1))
        return self._jit_park_lane

    def _effective_prompt(self, r: Request) -> np.ndarray:
        """Prompt + tokens already generated: greedy decode is
        deterministic, so a preempted request re-enters as if its output
        so far had been part of the prompt and continues its stream."""
        if not r.tokens_out:
            return r.prompt
        return np.concatenate(
            [np.asarray(r.prompt, np.int32),
             np.asarray(r.tokens_out, np.int32)])

    def _admit_paged(self, r: Request, sl: int, st) -> bool:
        """Page-aware admission of `r` into lane `sl`.

        Gate: enough free pages for the request's un-shared need, after
        LRU-evicting cached prefixes.  On a radix hit the lane reuses the
        shared pages (copy-on-write by page alignment: it only ever writes
        pages it owns exclusively) and skips prefill entirely — the un-hit
        suffix rides the decode loop's forced-token queue.  Returns False
        (nothing mutated, lookup refs released) when the pool can't cover
        it; the scheduler may then preempt-to-free.
        """
        pool = self.pool
        prompt = self._effective_prompt(r)
        rem_budget = r.max_new_tokens - len(r.tokens_out)
        need_pages = pool.pages_for(len(prompt) + rem_budget)
        hit_pages, hit_len = self.prefix_cache.lookup(prompt)
        if hit_len and len(prompt) - hit_len > self.max_hit_suffix:
            pool.decref(hit_pages)  # suffix too long: prefill is cheaper
            hit_pages, hit_len = [], 0
        own_need = need_pages - len(hit_pages)
        if own_need > pool.free_pages:
            self.prefix_cache.evict(own_need - pool.free_pages)
        if own_need > pool.free_pages:
            pool.decref(hit_pages)
            return False
        own = pool.alloc(own_need)
        pages = hit_pages + own
        pt_row = np.zeros((self.max_pages,), np.int32)
        pt_row[:len(pages)] = pages
        reset = np.zeros((self.max_pages,), np.int32)  # trash-page padded
        reset[:len(own)] = own
        self.stats["admitted"] += 1
        if hit_len:
            suffix = prompt[hit_len:]
            st["caches"] = self._admit_hit_fn()(
                st["caches"], sl, jnp.asarray(pt_row), hit_len,
                jnp.asarray(reset))
            frow = np.zeros((self.cache_len,), np.int32)
            frow[:len(suffix) - 1] = suffix[1:]
            (st["cur"], st["active"], st["eos"], st["budget"], st["forced"],
             st["flen"], st["fptr"]) = self._admit_lane_paged_fn()(
                st["cur"], st["active"], st["eos"], st["budget"],
                st["forced"], st["flen"], st["fptr"], sl, int(suffix[0]),
                r.eos_id, rem_budget, jnp.asarray(frow),
                len(suffix) - 1)
            self._lane_forced[sl] = len(suffix) - 1
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += int(hit_len)
            r.t_admitted = time.perf_counter()
        else:
            logits, small = self._prefill_prompts([prompt], 1,
                                                  bucket_cache=True)
            bucket = bucket_len(len(prompt), self.buckets, lane=8)
            n_wp = min(self.pool.pages_for(bucket), len(pages))
            st["caches"] = self._admit_cold_fn(bucket, n_wp)(
                st["caches"], small, sl, jnp.asarray(pt_row), len(prompt),
                jnp.asarray(reset), jnp.asarray(pages[:n_wp], np.int32))
            self.stats["prefills"] += 1
            # register the prompt's full pages for future prefix hits —
            # their KV is complete once the insert above runs (device
            # program order also sequences it before any later reader);
            # hit-path suffix pages are never registered because their KV
            # fills in over later decode dispatches and a preemption could
            # strand them half-written
            self.prefix_cache.insert(prompt, pages)
            tok = int(self._greedy_next(logits)[0])
            t_now = time.perf_counter()
            r.t_admitted = t_now
            r.append_token(tok, t_now)
            self._lane_forced[sl] = 0
            if not r.done:
                (st["cur"], st["active"], st["eos"], st["budget"],
                 st["forced"], st["flen"], st["fptr"]) = \
                    self._admit_lane_paged_fn()(
                        st["cur"], st["active"], st["eos"], st["budget"],
                        st["forced"], st["flen"], st["fptr"], sl, tok,
                        r.eos_id, r.max_new_tokens - len(r.tokens_out),
                        jnp.zeros((self.cache_len,), jnp.int32), 0)
        self._lane_pages[sl] = pages
        self.stats["pages_in_use"] = self.pool.pages_in_use
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self.pool.pages_in_use)
        return True

    def _release_lane(self, sl: int) -> None:
        """Return lane `sl`'s page references to the pool (tree references
        keep registered prefix pages alive for future hits)."""
        if self._lane_pages[sl] is not None:
            self.pool.decref(self._lane_pages[sl])
            self._lane_pages[sl] = None
        self._lane_forced[sl] = 0
        self.stats["pages_in_use"] = self.pool.pages_in_use

    def _preempt(self, slots, pending, st) -> bool:
        """Free pages by evicting the occupied lane with the most work
        left (it holds the most still-unearned pages).  The victim is
        re-queued with its stream intact — greedy decode is deterministic,
        so re-admission (usually a prefix hit on its own registered pages)
        continues exactly where it stopped."""
        occ = [(i, r) for i, r in enumerate(slots) if r is not None]
        if not occ:
            return False
        sl, victim = max(occ, key=lambda ir: ir[1].max_new_tokens
                         - len(ir[1].tokens_out))
        slots[sl] = None
        st["cur"], st["active"] = self._park_lane_fn()(
            st["cur"], st["active"], sl)
        self._release_lane(sl)
        pending.append(victim)
        self.stats["preemptions"] += 1
        return True

    def _reconcile_dispatch(self, toks, slots, done, n: int,
                            t_step: float) -> None:
        """Shared per-dispatch bookkeeping for the dense and paged loops:
        fetch the (n, B) token block (the only per-dispatch device sync),
        account stats, mirror the paged suffix-ingest consumption, append
        streams, and sweep completed lanes out of their slots."""
        block = np.asarray(toks)
        self.stats["decode_dispatches"] += 1
        self.stats["decode_steps"] += n
        self.stats["device_syncs"] += 1
        self.stats["active_lane_steps"] += \
            sum(r is not None for r in slots) * n
        if self.monitor is not None:
            self.monitor.observe(self.stats["decode_steps"],
                                 (time.perf_counter() - t_step) / n)
        if self.paged:
            for i in range(self.max_batch):  # host mirror of suffix ingest
                if slots[i] is not None:
                    self._lane_forced[i] = max(0, self._lane_forced[i] - n)
        self._append_block(block, slots, time.perf_counter())
        for i, r in enumerate(slots):
            if r is not None and r.done:
                done.append(r)
                slots[i] = None  # device lane already inactive
                if self.paged:
                    self._release_lane(i)
                self.stats["completed"] += 1

    # -- scheduler loop -------------------------------------------------------

    def run(self) -> List[Request]:
        """Serve until queue + slots drain; returns requests sorted by rid.

        Admission honours `Request.t_arrival` (seconds after this call), so
        a Poisson stream can be replayed by submitting everything up front.
        """
        if self.paged:
            return self._run_paged()
        if self._slot_caches is None:
            self._slot_caches = self._init_slot_caches()
        caches = self._slot_caches
        # decode/insert donate the cache buffers: until the loop finishes,
        # self._slot_caches may reference deleted arrays.  Drop the handle
        # so an abnormal exit (interrupt, OOM) re-allocates on the next run
        # instead of poisoning the engine; restored on normal completion.
        self._slot_caches = None
        done: List[Request] = []
        pending = self._queue
        self._queue = []
        slots: List[Optional[Request]] = [None] * self.max_batch
        # decode state lives on device between horizon boundaries; the host
        # only touches it on admission events (completions deactivate their
        # lane on device, inside the fused loop)
        cur = jnp.full((self.max_batch,), PAD_TOKEN, jnp.int32)
        active = jnp.zeros((self.max_batch,), bool)
        eos = jnp.full((self.max_batch,), -1, jnp.int32)
        budget = jnp.zeros((self.max_batch,), jnp.int32)
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)

        while pending or any(r is not None for r in slots):
            now = time.perf_counter() - t0
            free = [i for i, r in enumerate(slots) if r is None]
            arrived = [r for r in pending if r.t_arrival <= now]
            if free and arrived:
                pick = self.policy.select(
                    arrived, len(free),
                    warm=[b for (b, n, _) in self._jit_prefill if n == 1],
                    now=now)
                for r in [arrived[p] for p in pick]:
                    pending.remove(r)
                    sl = free.pop(0)
                    caches, tok = self._admit(r, sl, caches)
                    t_now = time.perf_counter()
                    r.t_admitted = t_now
                    r.append_token(tok, t_now)
                    if r.done:  # budget of 1 or instant EOS: slot stays free
                        done.append(r)
                        free.insert(0, sl)
                        self.stats["completed"] += 1
                    else:
                        slots[sl] = r
                        cur, active, eos, budget = self._admit_lane_fn()(
                            cur, active, eos, budget, sl, tok, r.eos_id,
                            r.max_new_tokens - len(r.tokens_out))
            if not any(r is not None for r in slots):
                if pending:  # idle until the next arrival
                    wait = min(r.t_arrival for r in pending) \
                        - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.005))
                continue

            n = self._pick_horizon(
                bool(pending),
                [r.max_new_tokens - len(r.tokens_out)
                 for r in slots if r is not None])
            t_step = time.perf_counter()
            toks, cur, active, budget, caches = self._decode_steps_fn(n)(
                self.params, caches, cur, active, eos, budget)
            self._reconcile_dispatch(toks, slots, done, n, t_step)

        self._slot_caches = caches
        return sorted(done, key=lambda r: r.rid)

    def _run_paged(self) -> List[Request]:
        """The paged scheduler loop: page-aware admission, prefix-hit
        suffix ingest through the forced-token queue, preempt-to-free
        under deadline pressure, page release on completion."""
        if self._slot_caches is None:
            self._slot_caches = self._init_slot_caches()
        # decode/admit programs donate the cache buffers — drop the handle
        # so an abnormal exit re-allocates instead of poisoning the engine
        st = {
            "caches": self._slot_caches,
            "cur": jnp.full((self.max_batch,), PAD_TOKEN, jnp.int32),
            "active": jnp.zeros((self.max_batch,), bool),
            "eos": jnp.full((self.max_batch,), -1, jnp.int32),
            "budget": jnp.zeros((self.max_batch,), jnp.int32),
            "forced": jnp.zeros((self.max_batch, self.cache_len), jnp.int32),
            "flen": jnp.zeros((self.max_batch,), jnp.int32),
            "fptr": jnp.zeros((self.max_batch,), jnp.int32),
        }
        self._slot_caches = None
        done: List[Request] = []
        pending = self._queue
        self._queue = []
        slots: List[Optional[Request]] = [None] * self.max_batch
        if not self._ladder_warm:
            # compile the whole horizon ladder + lane-state programs before
            # the first request lands by executing them on the empty
            # (all-inactive) state — semantically a no-op, but a compile
            # that instead fired mid-serving would stall every resident
            # lane (the decode-loop analogue of the admission policy's
            # warm-bucket preference).  The radix tree makes the horizon
            # schedule state-dependent, so "the warmup pass saw it" does
            # not cover later passes the way it does for dense slots.
            for n in self._horizons:
                toks, cur, active, budget, fptr, caches = \
                    self._decode_steps_fn(n)(
                        self.params, st["caches"], st["cur"], st["active"],
                        st["eos"], st["budget"], st["forced"], st["flen"],
                        st["fptr"])
                st.update(caches=caches, cur=cur, active=active,
                          budget=budget, fptr=fptr)
            trash_row = jnp.zeros((self.max_pages,), jnp.int32)
            st["caches"] = self._admit_hit_fn()(st["caches"], 0, trash_row,
                                                0, trash_row)
            (st["cur"], st["active"], st["eos"], st["budget"], st["forced"],
             st["flen"], st["fptr"]) = self._admit_lane_paged_fn()(
                st["cur"], st["active"], st["eos"], st["budget"],
                st["forced"], st["flen"], st["fptr"], 0, PAD_TOKEN, -1, 0,
                jnp.zeros((self.cache_len,), jnp.int32), 0)
            st["cur"], st["active"] = self._park_lane_fn()(
                st["cur"], st["active"], 0)
            self._ladder_warm = True
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)

        while pending or any(r is not None for r in slots):
            now = time.perf_counter() - t0
            free = [i for i, r in enumerate(slots) if r is None]
            arrived = [r for r in pending if r.t_arrival <= now]
            starved = None  # head-of-line request the pool couldn't cover
            if free and arrived:
                pick = self.policy.select(
                    arrived, len(free),
                    warm=[b for (b, n, _) in self._jit_prefill if n == 1],
                    now=now)
                for r in [arrived[p] for p in pick]:
                    if not free:
                        break
                    sl = free[0]
                    if not self._admit_paged(r, sl, st):
                        starved = r
                        break
                    free.pop(0)
                    pending.remove(r)
                    if r.done:  # budget of 1 / instant EOS at admission
                        done.append(r)
                        self._release_lane(sl)
                        self.stats["completed"] += 1
                    else:
                        slots[sl] = r
            if starved is not None and self.policy.deadline is not None \
                    and self.policy.deadline.overdue(
                        now - starved.t_arrival):
                # deadline pressure and no pages: preempt the lane with the
                # most work left; the starved request is retried next
                # boundary (often as a prefix hit on the victim's pages)
                self._preempt(slots, pending, st)
            if not any(r is not None for r in slots):
                if starved is not None:  # pool-starved with nothing running
                    time.sleep(0.0005)   # (eviction frees pages next pass)
                elif pending:  # idle until the next arrival
                    wait = min(r.t_arrival for r in pending) \
                        - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.005))
                continue

            remaining = [self._lane_forced[i]
                         + r.max_new_tokens - len(r.tokens_out)
                         for i, r in enumerate(slots) if r is not None]
            n = self._pick_horizon(bool(pending), remaining)
            t_step = time.perf_counter()
            toks, cur, active, budget, fptr, caches = \
                self._decode_steps_fn(n)(
                    self.params, st["caches"], st["cur"], st["active"],
                    st["eos"], st["budget"], st["forced"], st["flen"],
                    st["fptr"])
            st.update(caches=caches, cur=cur, active=active, budget=budget,
                      fptr=fptr)
            self._reconcile_dispatch(toks, slots, done, n, t_step)

        # slot-accounting invariant: when drained, the only live page
        # references are the radix tree's — anything else is a leak
        assert all(p is None for p in self._lane_pages), self._lane_pages
        assert self.pool.pages_in_use == self.prefix_cache.cached_pages, (
            self.pool.pages_in_use, self.prefix_cache.cached_pages)
        self._slot_caches = st["caches"]
        return sorted(done, key=lambda r: r.rid)


class WaveEngine(EngineBase):
    """The seed's batch-synchronous scheduler, kept as the measured baseline.

    One batched prefill per wave, decode until every member finishes.  The
    seed's dead deadline loop is gone (the deadline governs admission order
    in the continuous engine instead), and finished rows feed PAD_TOKEN —
    their cache rows are frozen by the decode active mask rather than
    absorbing stale writes.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.stats.update(waves=0)

    def run(self) -> List[Request]:
        done: List[Request] = []
        pending = self._queue
        self._queue = []
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)
        deadline_s = self.policy.deadline.deadline_s
        while pending:
            # deadline batching: launch a partial wave at the deadline with
            # whatever requests arrived, instead of waiting for a full batch
            while True:
                now = time.perf_counter() - t0
                arrived = [r for r in pending if r.t_arrival <= now]
                if len(arrived) >= self.max_batch:
                    break
                if len(arrived) == len(pending):
                    break  # nobody else can join: don't sit out the deadline
                if arrived and now - min(
                        r.t_arrival for r in arrived) >= deadline_s:
                    break
                nxt = min((r.t_arrival for r in pending
                           if r.t_arrival > now), default=float("inf"))
                wake = min([nxt] + [r.t_arrival + deadline_s
                                    for r in arrived])
                time.sleep(max(min(wake - now, 0.005), 0.0005))
            wave = arrived[: self.max_batch]
            for r in wave:
                pending.remove(r)
            done += self._serve_wave(wave)
        return done

    def _serve_wave(self, wave: List[Request]) -> List[Request]:
        self.stats["waves"] += 1
        b = len(wave)
        logits, caches = self._prefill_batch(wave, b)
        nxt = self._greedy_next(logits)
        now = time.perf_counter()
        for i, r in enumerate(wave):
            r.append_token(int(nxt[i]), now)
        # decode state moves to device once per wave; the fused horizon
        # loop feeds tokens back on device and ships (n, b) blocks out
        cur = jnp.asarray([PAD_TOKEN if r.done else r.tokens_out[-1]
                           for r in wave], jnp.int32)
        active = jnp.asarray([not r.done for r in wave])
        eos = jnp.asarray([r.eos_id for r in wave], jnp.int32)
        budget = jnp.asarray([r.max_new_tokens - len(r.tokens_out)
                              for r in wave], jnp.int32)

        while not all(r.done for r in wave):
            n = self._pick_horizon(
                False, [r.max_new_tokens - len(r.tokens_out)
                        for r in wave if not r.done])
            t_step = time.perf_counter()
            toks, cur, active, budget, caches = self._decode_steps_fn(n)(
                self.params, caches, cur, active, eos, budget)
            block = np.asarray(toks)
            self.stats["decode_dispatches"] += 1
            self.stats["decode_steps"] += n
            self.stats["device_syncs"] += 1
            if self.monitor is not None:
                self.monitor.observe(self.stats["decode_steps"],
                                     (time.perf_counter() - t_step) / n)
            self._append_block(block, wave, time.perf_counter())
        return wave


# the slot-based continuous-batching engine is the serving default
ServingEngine = ContinuousBatchingEngine
