"""Serving engines: the composition root over the three serving layers.

serving/scheduler.py holds host-side policy only (admission ordering, the
decode-horizon ladder, preemption choice, stream reconciliation — no
jax); serving/executor.py holds every jitted program plus plan placement
(the `mode="serve"` kv-head-sharded paged path and the
`mode="serve_pipeline"` stage-streaming decode); serving/kv_manager.py
owns paged-KV memory (page pool, radix prefix cache, page tables).  This
module wires the three together behind the old monolith's public API
(semantics in docs/serving.md).
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.packing import bucket_len
from repro.models.transformer import Model
from repro.runtime.stragglers import StragglerMonitor
from repro.serving.executor import PAD_TOKEN, Executor
from repro.serving.kv_manager import (KVManager, kv_page_bytes,
                                      num_pages_for_hbm, paged_eligible)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "WaveEngine", "ServingEngine",
           "Request", "EngineBase", "PAD_TOKEN", "kv_page_bytes",
           "num_pages_for_hbm"]


class EngineBase:
    """Shared composition: scheduler + executor, stats, prefill plumbing."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 buckets=(32, 64, 128, 256), greedy: bool = True,
                 deadline_s: float = 0.05, plan=None,
                 max_decode_len: int = 64, decode_horizon: int = 8,
                 monitor: Optional[StragglerMonitor] = None,
                 quant_weights: bool = False):
        self.model = model
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets))
        self.greedy = greedy
        self.plan = plan
        self.monitor = monitor
        self.quant_weights = bool(quant_weights)
        self.paged = False  # ContinuousBatchingEngine may flip this
        # slot rows: prompt KV + decode headroom, fixed per engine
        self.cache_len = bucket_len(max(self.buckets), self.buckets,
                                    lane=8) + max_decode_len
        self.decode_horizon = decode_horizon
        self.sched = Scheduler(self.buckets, deadline_s, decode_horizon,
                               max_batch)
        self.executor = Executor(model, params, plan=plan,
                                 quant_weights=quant_weights,
                                 max_batch=max_batch,
                                 cache_len=self.cache_len,
                                 buckets=self.buckets)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "device_syncs": 0}

    params = property(lambda self: self.executor.params)
    policy = property(lambda self: self.sched.policy)

    def submit(self, req: Request) -> None:
        need = self.policy.bucket_of(len(req.prompt)) + req.max_new_tokens
        # speculation scatters up to spec_k rows past the committed
        # position; the lane's page table must cover the overshoot
        need += getattr(self, "spec_k", 0) if getattr(self, "spec", False) \
            else 0
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: bucket+budget {need} exceeds slot "
                f"cache_len {self.cache_len} (raise max_decode_len)")
        if self.paged and self.kv.pages_for(need) > self.kv.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: needs {self.kv.pages_for(need)} pages,"
                f" pool has {self.kv.num_pages - 1} (raise num_pages)")
        self.sched.enqueue(req)

    def _prefill(self, prompts, batch: int, bucket_cache: bool = False):
        self.stats["prefill_tokens"] += int(sum(len(p) for p in prompts))
        return self.executor.prefill_prompts(prompts, batch,
                                             bucket_cache=bucket_cache)

    def _greedy_next(self, logits) -> np.ndarray:
        self.stats["device_syncs"] += 1
        return np.asarray(jnp.argmax(logits, -1), np.int32)


class ContinuousBatchingEngine(EngineBase):
    """Slot-asynchronous scheduler: admit into freed slots between steps.

    ``paged`` (default "auto") swaps dense slot rows for the page arena +
    radix prefix cache; ``kv_dtype="int8"`` quantizes the arena;
    ``quant_weights=True`` serves W8A8 — all three compose with a
    ClusterPlan, whose serve mode shards the arena's kv-head dim across
    the mesh (docs/serving.md §sharded serving)."""

    def __init__(self, *args, paged="auto", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_hit_suffix: Optional[int] = None,
                 kv_dtype: str = "bf16",
                 spec_config: Optional[dict] = None,
                 disagg: Optional[Tuple[int, int]] = None, **kw):
        super().__init__(*args, **kw)
        self.disagg = tuple(disagg) if disagg is not None else None
        self.stats.update(admitted=0, completed=0, prefills=0,
                          active_lane_steps=0)
        self._slot_caches = None
        self._draft_slot_caches = None
        eligible = paged_eligible(self.model.cfg, self.plan)
        if paged == "auto":
            paged = eligible
        elif paged and not eligible:
            raise ValueError(
                "paged KV needs an all-attention, unwindowed, causal model "
                "(recurrent state and ring buffers have no paged analogue) "
                "under no plan, a mode='serve' plan, or a throughput "
                "(exact=False) serve_pipeline plan (the exact pipeline "
                "streams the dense slot path)")
        self.paged = bool(paged)
        # throughput pipeline (exact=False serve_pipeline): stage-local
        # arenas + lane groups, one group per stage schedule offset
        self._stage_n = 0
        if (self.plan is not None and self.plan.mode == "serve_pipeline"
                and not getattr(self.plan, "exact", True)):
            if spec_config:
                raise ValueError(
                    "spec_config does not compose with the request-skewed "
                    "serve_pipeline plan (the spec program has no skewed "
                    "schedule); serve speculation from a mode='serve' plan")
            self._stage_n = self.plan.mesh.shape[self.plan.axes.stage]
            if self.max_batch % self._stage_n:
                raise ValueError(
                    f"request-skewed serve_pipeline splits the batch into "
                    f"one lane group per stage: max_batch={self.max_batch} "
                    f"must be a multiple of the stage count "
                    f"{self._stage_n}")
            self.sched.set_lane_groups(self._stage_n)
        assert kv_dtype in ("bf16", "int8"), kv_dtype
        if kv_dtype == "int8" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' needs the paged KV pool (quantized dense "
                "slot rows are not implemented); this model/config fell "
                "back to dense slots")
        self.kv_dtype = kv_dtype
        self.kv: Optional[KVManager] = None
        self.spec = bool(spec_config)
        if self.spec and not self.paged:
            raise ValueError(
                "spec_config needs the paged KV pool: the draft arena and "
                "the batched verify both address KV through page tables")
        if self.paged:
            self.page_size = page_size
            # whole-page capacity: gathered paged layout == dense slot row
            self.cache_len = -(-self.cache_len // page_size) * page_size
            self.executor.cache_len = self.cache_len
            self.max_pages = self.cache_len // page_size
            if num_pages is None:  # default: dense table capacity + trash
                num_pages = self.max_batch * self.max_pages + 1
            draft_num_pages = 0
            if self.spec:
                # draft arena default: position parity with the target —
                # every target page the pool can hand a lane has a draft
                # twin (kv_manager.spec_pool_split sizes both from one
                # HBM byte budget when the caller wants byte parity)
                self.spec_k = int(spec_config.get("spec_k", 4))
                assert self.spec_k >= 1
                self.draft_model: Model = spec_config["draft_model"]
                draft_num_pages = int(spec_config.get("draft_num_pages")
                                      or num_pages)
                self.executor.set_draft(self.draft_model,
                                        spec_config["draft_params"])
                self.sched.set_spec(self.spec_k)
                self._spec_warm = False
                self._tpos = [0] * self.max_batch   # target positions
                self._dpos = [0] * self.max_batch   # draft positions
                self.stats.update(spec_dispatches=0, spec_draft_steps=0,
                                  spec_accepted=0, spec_proposed=0,
                                  spec_draft_prefills=0,
                                  spec_catchup_tokens=0)
            self.kv = KVManager(num_pages, page_size, self.max_batch,
                                self.max_pages,
                                draft_num_pages=draft_num_pages,
                                shards=self._stage_n or 1)
            self.max_hit_suffix = (max(self.buckets)
                                   if max_hit_suffix is None
                                   else max_hit_suffix)
            self._ladder_warm = False
            self.stats.update(prefix_hits=0, prefix_hit_tokens=0,
                              preemptions=0, pages_in_use=0, pages_peak=0)
        if self.disagg is not None:
            self._init_disagg()

    def _init_disagg(self) -> None:
        """Disaggregated prefill/decode pools (`disagg=(P, D)`): devices
        [0, P) become the prefill pool, [P, P+D) the decode pool.  The
        prefill pool owns bucketed prefill + a transient staging arena;
        completed pages ship into the decode arena (executor.ship_pages)
        and ownership moves with them.  One radix tree — the decode
        pool's — spans both: it indexes decode-arena pages only, so a
        prefix hit admits without touching the prefill pool at all."""
        import jax
        p, d = self.disagg
        if not self.paged:
            raise ValueError(
                "disagg needs the paged KV pool: page shipping is the "
                "ownership-handoff mechanism (dense slot rows have no "
                "transferable unit)")
        if self.plan is not None:
            raise ValueError(
                "disagg does not compose with a ClusterPlan (the plan "
                "already owns device placement); pick one")
        if self.spec:
            raise ValueError(
                "disagg does not compose with spec_config (the draft "
                "arena has no shipping path yet)")
        devices = jax.devices()
        if p < 1 or d < 1 or p + d > len(devices):
            raise ValueError(
                f"disagg={self.disagg}: needs prefill >= 1, decode >= 1, "
                f"prefill+decode <= {len(devices)} host devices")
        self.executor.set_disagg(devices[:p], devices[p:p + d])
        # staging KV: one admission in flight, so max_pages + trash always
        # covers the export; its ledgers see the same actual-freed
        # accounting as the decode pool's
        self.kv_prefill = KVManager(self.max_pages + 1, self.page_size, 1,
                                    self.max_pages)
        self._prefill_arena = None
        self.stats.update(shipped_pages=0, shipped_bytes=0,
                          ship_dispatches=0)
        # queue split: the radix peek classifies pending requests into the
        # decode-ingest queue (hit: admits decode-side, zero transfers)
        # vs the prefill queue, and drives the pool-aware occupancy
        # signals the fleet router reads (scheduler.set_disagg).
        # prefill_chunk=max_batch keeps in-process admission
        # work-conserving — the pools drain sequentially on this host, so
        # throttling colds below free-slot capacity only delays them; the
        # SJF ordering alone is what shields steady short traffic from a
        # long-prompt burst.  A cross-host prefill pool with real
        # per-cycle capacity would lower the chunk to its worker count.
        self.sched.set_disagg(
            lambda r: self.kv.peek_hit(np.asarray(r.effective_prompt())),
            prefill_chunk=self.max_batch)

    pool = property(lambda self: self.kv.pool)
    prefix_cache = property(lambda self: self.kv.prefix_cache)
    _lane_pages = property(lambda self: self.kv._lane_pages)

    def hit_stats(self) -> dict:
        """Prefix-reuse summary with the derived hit rate — the per-replica
        figure the fleet router aggregates (serving/replica.py); dense
        engines report zeros (no radix tree to hit)."""
        s = self.stats
        admitted = s.get("admitted", 0)
        hits = s.get("prefix_hits", 0)
        return {"admitted": admitted, "prefix_hits": hits,
                "prefix_hit_tokens": s.get("prefix_hit_tokens", 0),
                "prefix_hit_rate": hits / admitted if admitted else 0.0}

    def kv_page_bytes(self) -> int:
        """Per-device HBM bytes one arena page costs at this engine's
        kv_dtype (stage-sharded arenas hold 1/stages of the stack)."""
        return kv_page_bytes(self.model.cfg, self.page_size, self.kv_dtype,
                             shards=self.kv.shards if self.kv else 1)

    def _admit_dense(self, r: Request, sl: int, st) -> bool:
        """Batch-1 prefill + insert into slot `sl`; TTFT paid here."""
        logits, small = self._prefill([r.prompt], 1, bucket_cache=True)
        st["caches"] = self.executor.insert(st["caches"], small, sl)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        self._first_token(r, int(self._greedy_next(logits)[0]))
        if not r.done:
            self.executor.admit_lane(st, sl, r.tokens_out[-1], r.eos_id,
                                     r.remaining())
        return True

    def _admit_paged(self, r: Request, sl: int, st) -> bool:
        """Radix hit -> reuse shared pages, suffix rides the forced-token
        queue; cold -> bucket prefill scattered into owned pages + prompt
        registered.  False = pool can't cover it (nothing held)."""
        prompt = r.effective_prompt()
        grant = self.kv.admit(prompt, r.remaining(), self.max_hit_suffix,
                              spec_margin=getattr(self, "spec_k", 0)
                              if self.spec else 0)
        if grant is None:
            return False
        self.stats["admitted"] += 1
        if grant.hit_len:
            suffix = prompt[grant.hit_len:]
            self.executor.admit_hit(st, sl, grant.pt_row, grant.hit_len,
                                    grant.reset)
            self.executor.admit_lane_paged(st, sl, int(suffix[0]), r.eos_id,
                                           r.remaining(), suffix[1:],
                                           len(suffix) - 1)
            self.sched.lane_forced[sl] = len(suffix) - 1
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += int(grant.hit_len)
            r.t_admitted = time.perf_counter()
        else:
            logits, small = self._prefill([prompt], 1, bucket_cache=True)
            bucket = bucket_len(len(prompt), self.buckets, lane=8)
            n_wp = min(self.kv.pages_for(bucket), len(grant.pages))
            if self.disagg is not None:
                self._ship_cold(st, sl, small, grant, prompt, bucket, n_wp)
            else:
                self.executor.admit_cold(
                    st, sl, small, grant.pt_row, len(prompt), grant.reset,
                    np.asarray(grant.pages[:n_wp], np.int32), bucket)
            self.stats["prefills"] += 1
            self.kv.register_prefix(prompt, grant.pages)
            self._first_token(r, int(self._greedy_next(logits)[0]))
            self.sched.lane_forced[sl] = 0
            if not r.done:
                self.executor.admit_lane_paged(
                    st, sl, r.tokens_out[-1], r.eos_id, r.remaining(),
                    np.zeros((0,), np.int32), 0)
        self.kv.commit(sl, grant)
        if self.spec and not r.done:
            self._admit_draft(r, sl, st, grant, prompt)
        self.stats["pages_in_use"] = self.kv.pages_in_use
        self.stats["pages_peak"] = max(self.stats["pages_peak"],
                                       self.kv.pages_in_use)
        return True

    def _ship_cold(self, st, sl: int, small, grant, prompt, bucket: int,
                   n_wp: int) -> None:
        """The disaggregated ownership handoff for one cold admission.

        The request is *prefill-owned* while its bucket cache scatters
        into staging pages on the prefill pool's arena, then
        *decode-owned* once `ship_pages` lands those pages in the lane's
        granted decode pages.  admit_hit first sentinels every granted
        page and points the lane's table row at them, so the shipped page
        contents (bitwise what admit_cold would have written, including
        partial-page sentinel kpos and int8 scale planes) arrive into an
        arena state identical to colocated serving's."""
        src = self.kv_prefill.stage_export(n_wp)
        self._prefill_arena = self.executor.prefill_admit(
            self._prefill_arena, small, src.pt_row, len(prompt), src.reset,
            np.asarray(src.pages, np.int32), bucket)
        self.executor.admit_hit(st, sl, grant.pt_row, len(prompt),
                                grant.reset)
        self.executor.ship_pages(self._prefill_arena, st, src.pages,
                                 grant.pages[:n_wp])
        self.kv_prefill.finish_export(src.pages)
        self.stats["ship_dispatches"] += 1
        self.stats["shipped_pages"] += n_wp
        self.stats["shipped_bytes"] += n_wp * self.kv_page_bytes()

    def _admit_draft(self, r: Request, sl: int, st, grant, prompt) -> None:
        """Bring the lane's draft cache to the target's position: a cold
        lane prefills the full effective prompt on the draft model, a
        prefix-hit lane prefills prompt[:hit_len] (page-aligned, so the
        remaining suffix ingests in lockstep through the spec program's
        forced queue — the draft has no radix tree to hit on)."""
        plen = grant.hit_len if grant.hit_len else len(prompt)
        small, bucket = self.executor.draft_prefill_prompts(
            [prompt[:plen]], 1)
        n_wp = min(self.kv.draft_pool.pages_for(bucket),
                   len(grant.draft_pages))
        self.executor.admit_cold_draft(
            st, sl, small, grant.draft_pt_row, plen, grant.draft_reset,
            np.asarray(grant.draft_pages[:n_wp], np.int32), bucket)
        self._tpos[sl] = self._dpos[sl] = int(plen)
        self.sched.reset_lane_spec(sl)
        self.stats["spec_draft_prefills"] += 1

    @staticmethod
    def _first_token(r: Request, tok: int) -> None:
        t_now = time.perf_counter()
        r.t_admitted = t_now
        r.append_token(tok, t_now)

    def _release(self, sl: int) -> None:
        self.kv.release(sl)
        self.sched.lane_forced[sl] = 0
        if self.spec:
            self._tpos[sl] = self._dpos[sl] = 0
        self.stats["pages_in_use"] = self.kv.pages_in_use

    def _preempt(self, slots, pending, st) -> None:
        """Evict the lane with the most work left; greedy decode is
        deterministic, so the re-queued victim (usually a prefix hit on
        its own pages) continues exactly where it stopped.  The victim's
        preemption counter feeds the scheduler's cascade damping: at the
        budget it becomes victim-exempt and admission-priority."""
        sl = self.sched.victim(slots)
        if sl is None:
            return
        victim, slots[sl] = slots[sl], None
        victim.n_preempts += 1
        self.executor.park_lane(st, sl)
        self._release(sl)
        pending.append(victim)
        self.stats["preemptions"] += 1

    def _advance_mirrors(self, block: np.ndarray, slots, n: int) -> None:
        """Advance the host position mirrors by what the device consumed:
        per active lane, min(pending forced, n) swallowed positions plus
        one position per emitted token (every consumed step is one or the
        other — decode_steps and the spec block share this invariant)."""
        for i, r in enumerate(slots):
            if r is None:
                continue
            emitted = int((block[:, i] >= 0).sum())
            self._tpos[i] += min(self.sched.lane_forced[i], n) + emitted

    def _reconcile(self, toks, slots, done, n: int, t_step: float) -> None:
        block = np.asarray(toks)  # the only per-dispatch device sync
        if self.monitor is not None:
            self.monitor.observe(self.stats["decode_steps"] + n,
                                 (time.perf_counter() - t_step) / n)
        if self.spec:  # spec-disabled dispatch: draft lags, catchup later
            self._advance_mirrors(block, slots, n)
        self.sched.reconcile(block, slots, done, n, self.stats,
                             time.perf_counter(), self.paged,
                             self._release if self.paged else None)

    def _reconcile_spec(self, toks, slots, done, k: int,
                        t_step: float) -> None:
        """Spec-dispatch bookkeeping: one (k+1, B) block per dispatch;
        acceptance feedback drives the per-lane depth ladder, and the
        position mirrors advance in lockstep on both caches (the device
        rewound them together)."""
        block = np.asarray(toks)  # the only per-dispatch device sync
        if self.monitor is not None:
            self.monitor.observe(self.stats["decode_steps"] + k + 1,
                                 (time.perf_counter() - t_step) / (k + 1))
        self.stats["spec_dispatches"] += 1
        self.stats["spec_draft_steps"] += k + 1
        self._advance_mirrors(block, slots, k + 1)
        for i, r in enumerate(slots):
            if r is None:
                continue
            self._dpos[i] = self._tpos[i]  # verify + rewind keep them equal
            emitted = int((block[:, i] >= 0).sum())
            if emitted >= 1 and self.sched.lane_forced[i] == 0:
                # emitted = 1 guaranteed + accepted drafts (+ bonus);
                # forced-ingest dispatches say nothing about the draft
                accepted = min(emitted - 1, k)
                self.stats["spec_accepted"] += accepted
                self.stats["spec_proposed"] += k
                self.sched.observe_acceptance(i, accepted, k)
        self.sched.reconcile(block, slots, done, k + 1, self.stats,
                             time.perf_counter(), self.paged,
                             self._release)

    def _spec_catchup(self, slots, st) -> None:
        """Feed draft lanes the stream tokens the target consumed during
        spec-disabled dispatches, so the draft cache re-enters speculation
        at the target's exact position."""
        lags = [(self._tpos[i] - self._dpos[i]) if r is not None else 0
                for i, r in enumerate(slots)]
        width = max(lags)
        if width <= 0:
            return
        tokens = np.zeros((self.max_batch, width), np.int32)
        for i, r in enumerate(slots):
            if r is None or lags[i] == 0:
                continue
            stream = np.concatenate(
                [np.asarray(r.prompt, np.int32),
                 np.asarray(r.tokens_out, np.int32)])
            tokens[i, :lags[i]] = stream[self._dpos[i]:self._tpos[i]]
            self._dpos[i] = self._tpos[i]
        self.executor.draft_catchup(st, tokens,
                                    np.asarray(lags, np.int32))
        self.stats["spec_catchup_tokens"] += int(sum(lags))

    def run(self) -> List[Request]:
        """Serve until queue + slots drain; returns requests sorted by rid.
        Admission honours `Request.t_arrival` (seconds after this call)."""
        if self._slot_caches is None:
            self._slot_caches = self.executor.init_caches(
                self.paged, *((self.page_size, self.kv.num_pages,
                               self.max_pages, self.kv_dtype)
                              if self.paged else ()))
        if self.spec and self._draft_slot_caches is None:
            self._draft_slot_caches = self.executor.init_draft_caches(
                self.page_size, self.kv.draft_pool.num_pages,
                self.max_pages, self.kv_dtype)
        if self.disagg is not None and self._prefill_arena is None:
            self._prefill_arena = self.executor.init_prefill_arena(
                self.page_size, self.kv_prefill.num_pages, self.max_pages,
                self.kv_dtype)
        st = self.executor.fresh_state(
            self._slot_caches, self.paged,
            draft_caches=self._draft_slot_caches if self.spec else None)
        # programs donate the caches: drop the handles (abnormal-exit safety)
        self._slot_caches = None
        self._draft_slot_caches = None
        if self.paged and not self._ladder_warm:
            self.executor.warm_ladder(st, self.sched.horizons)
            self._ladder_warm = True
        if self.spec and not self._spec_warm:
            self.executor.warm_spec(st, self.sched.spec_ladder)
            self._spec_warm = True
        done: List[Request] = []
        pending = self.sched.take_queue()
        slots: List[Optional[Request]] = [None] * self.max_batch
        admit = self._admit_paged if self.paged else self._admit_dense
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)

        while pending or any(r is not None for r in slots):
            now = time.perf_counter() - t0
            free = self.sched.order_free(
                [i for i, r in enumerate(slots) if r is None], slots)
            admitted, starved = self.sched.admission_cycle(
                pending, free, now, self.executor.warm_buckets,
                lambda r, sl: admit(r, sl, st))
            for r, sl in admitted:
                pending.remove(r)
                if r.done:  # budget of 1 / instant EOS at admission
                    done.append(r)
                    if self.paged:
                        self._release(sl)
                    free.insert(0, sl)
                    self.stats["completed"] += 1
                else:
                    slots[sl] = r
            if self.sched.should_preempt(starved, now):
                self._preempt(slots, pending, st)
            if not any(r is not None for r in slots):
                self.sched.idle_wait(pending, starved,
                                     time.perf_counter() - t0)
                continue

            k = (self.sched.spec_depth(slots, starved is not None)
                 if self.spec else 0)
            t_step = time.perf_counter()
            if k:
                self._spec_catchup(slots, st)
                toks = self.executor.spec_decode(st, k)
                self._reconcile_spec(toks, slots, done, k, t_step)
            else:
                n = self.sched.pick_horizon(bool(pending),
                                            self.sched.lane_remaining(slots))
                toks = self.executor.decode(st, n, self.paged)
                self._reconcile(toks, slots, done, n, t_step)

        if self.paged:
            self.kv.assert_drained()
            if self.disagg is not None:
                # exports are transient: every staged page was returned by
                # finish_export before its admission completed
                self.kv_prefill.assert_drained()
        self._slot_caches = st["caches"]
        self._draft_slot_caches = st.get("draft_caches")
        return sorted(done, key=lambda r: r.rid)


def __getattr__(name):  # PEP 562: WaveEngine (serving/wave.py) subclasses
    if name == "WaveEngine":  # EngineBase — lazy both ways, no import cycle
        from repro.serving.wave import WaveEngine
        return WaveEngine
    raise AttributeError(name)


ServingEngine = ContinuousBatchingEngine
