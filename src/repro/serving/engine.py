"""Batched serving engine: wave-scheduled prefill + decode.

The paper's system is an inference pipeline fed by an input FPGA at line
rate (§8.2), with the no-padding optimization cutting latency on short GLUE
sequences.  Our engine serves batched requests the same way:

  * requests are bucketed to the smallest compiled prompt length
    (core/packing.bucket_len — the minimum-padding rule)
  * a wave = up to `max_batch` requests: one batched prefill, then decode
    steps until every request hit its token budget or EOS
  * a deadline (stragglers.py) launches partial waves instead of waiting
  * jit programs are cached per (bucket, batch) so steady-state serving
    never recompiles
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import bucket_len
from repro.models.transformer import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, model: Model, params, max_batch: int = 8,
                 buckets=(32, 64, 128, 256), greedy: bool = True,
                 deadline_s: float = 0.05):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.buckets = buckets
        self.greedy = greedy
        self.deadline_s = deadline_s
        self._queue: List[Request] = []
        self._jit_prefill: Dict[tuple, Callable] = {}
        self._jit_decode: Optional[Callable] = None
        self.stats = {"waves": 0, "prefill_tokens": 0, "decode_steps": 0}

    # -- public ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.t_enqueue = time.perf_counter()
        self._queue.append(req)

    def run(self) -> List[Request]:
        """Serve until the queue drains; returns completed requests."""
        done: List[Request] = []
        while self._queue:
            wave = self._take_wave()
            done += self._serve_wave(wave)
        return done

    # -- internals ---------------------------------------------------------------

    def _take_wave(self) -> List[Request]:
        t0 = time.perf_counter()
        while (len(self._queue) < self.max_batch
               and time.perf_counter() - t0 < self.deadline_s):
            break  # single-threaded here: the deadline matters with async submit
        wave = self._queue[: self.max_batch]
        self._queue = self._queue[self.max_batch:]
        return wave

    def _prefill_fn(self, bucket: int, batch: int):
        key = (bucket, batch)
        if key not in self._jit_prefill:
            def fn(params, tokens, positions, lengths):
                caches = self.model.init_cache(batch, bucket + 64)
                logits, caches = self.model.prefill(
                    params, caches, tokens=tokens, positions=positions,
                    last_idx=lengths - 1)
                return logits, caches

            self._jit_prefill[key] = jax.jit(fn)
        return self._jit_prefill[key]

    def _decode_fn(self):
        if self._jit_decode is None:
            def fn(params, caches, token):
                return self.model.decode_step(params, caches, token)

            self._jit_decode = jax.jit(fn)
        return self._jit_decode

    def _serve_wave(self, wave: List[Request]) -> List[Request]:
        self.stats["waves"] += 1
        b = len(wave)
        maxlen = max(len(r.prompt) for r in wave)
        bucket = bucket_len(maxlen, self.buckets, lane=8)
        toks = np.zeros((b, bucket), np.int32)
        # left-aligned prompts; pad positions = 2^30 so the causal mask can
        # never attend to them (and cache slot i == position i for decode)
        pos = np.full((b, bucket), 2**30, np.int32)
        for i, r in enumerate(wave):
            n = len(r.prompt)
            toks[i, :n] = r.prompt
            pos[i, :n] = np.arange(n)
        lengths = np.array([len(r.prompt) for r in wave], np.int32)
        self.stats["prefill_tokens"] += int(lengths.sum())

        logits, caches = self._prefill_fn(bucket, b)(
            self.params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(lengths))
        decode = self._decode_fn()
        now = time.perf_counter()
        cur = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i, r in enumerate(wave):
            t = int(cur[i])
            r.tokens_out.append(t)
            r.t_first_token = now
            if t == r.eos_id or r.max_new_tokens <= 1:
                r.done = True
                r.t_done = now

        budget = max(r.max_new_tokens for r in wave)
        if all(r.done for r in wave):
            budget = 0
        for _ in range(budget - 1):
            logits, caches = decode(self.params, caches, jnp.asarray(cur))
            self.stats["decode_steps"] += 1
            cur = np.asarray(jnp.argmax(logits, -1), np.int32)
            alive = False
            for i, r in enumerate(wave):
                if r.done or len(r.tokens_out) >= r.max_new_tokens:
                    continue
                t = int(cur[i])
                r.tokens_out.append(t)
                if t == r.eos_id or len(r.tokens_out) >= r.max_new_tokens:
                    r.done = True
                    r.t_done = time.perf_counter()
                else:
                    alive = True
            if not alive:
                break
        for r in wave:
            r.done = True
            if not r.t_done:
                r.t_done = time.perf_counter()
        return wave
