"""Serving stack: slot-based continuous batching driven by the Cluster plan.

The paper's deployment is a spatial pipeline fed at line rate (§8.2):
requests stream through the 6-FPGA encoder cluster continuously, never
waiting for a "wave" to fill.  The engine mirrors that with *slots*:

  * a persistent KV cache with `max_batch` slot rows, allocated once per
    (slot, cache_len) shape and sharded by the Cluster-Builder serve-mode
    cache specs (`build_plan(..., mode="serve")`);
  * prefill-on-admission: a freed slot is refilled between decode steps by
    a batch-1 bucketed prefill whose cache is written into the slot row via
    a jitted `insert_prefill_cache` — the rest of the batch keeps decoding,
    nothing is torn down;
  * an admission policy (core/packing.AdmissionPolicy) that orders waiting
    requests by deadline overdue-ness (runtime/stragglers.AdmissionDeadline)
    then bucket warmth, so steady state never stalls on a prefill compile;
  * plan-aware execution: with a `ClusterPlan`, params and the slot cache
    are placed with `jax.device_put` under the plan's `NamedSharding`s and
    prefill/decode are jitted with `in_shardings`/`out_shardings` — the
    engine is the runtime consumer of the Cluster Builder's serve plan.

Decode runs on a *horizon*: each dispatch is a fused on-device loop
(`Model.decode_steps` — decode, greedy argmax, feed back, EOS/budget lane
masking, all under one jit) of up to `decode_horizon` steps, and the host
fetches one (n, B) int32 token block instead of one (B, V) logits array per
token.  The horizon is picked adaptively from admission pressure: with
waiting requests it stops at the next predicted completion so a slot frees
at the earliest boundary; with a drained queue it runs long.  Admissions
and completions are reconciled only at horizon boundaries; between them
the decode state (current token, active lanes, budgets) never leaves the
device.  `decode_horizon=1` reproduces the one-dispatch-per-token
scheduler and is the measured baseline in `benchmarks/run.py serve_cb`.

`WaveEngine` keeps the seed's batch-synchronous scheduler (one batched
prefill, decode to the slowest request) as the measured baseline for the
`benchmarks/run.py serve_cb` comparison; its inner loop rides the same
fused horizon programs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.packing import AdmissionPolicy, bucket_len
from repro.models.transformer import Model
from repro.runtime.stragglers import AdmissionDeadline, StragglerMonitor

PAD_TOKEN = 0  # fed for finished/free slot rows; their logits are never read


@dataclass(eq=False)  # identity equality: rid is caller-chosen, prompt is a
class Request:        # numpy array (== would be ambiguous), requests mutate
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    t_arrival: float = 0.0  # seconds after engine start (Poisson streams)
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    def append_token(self, tok: int, now: float) -> None:
        assert not self.done, \
            f"request {self.rid}: token appended after done"
        if not self.tokens_out:
            self.t_first_token = now
        self.tokens_out.append(int(tok))
        if tok == self.eos_id or len(self.tokens_out) >= self.max_new_tokens:
            self.done = True
            self.t_done = now


class EngineBase:
    """Shared plumbing: plan placement, jit caches, bucketed prefill."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 buckets=(32, 64, 128, 256), greedy: bool = True,
                 deadline_s: float = 0.05, plan=None,
                 max_decode_len: int = 64,
                 decode_horizon: int = 8,
                 monitor: Optional[StragglerMonitor] = None):
        self.model = model
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets))
        self.greedy = greedy
        self.plan = plan
        self.monitor = monitor
        self.policy = AdmissionPolicy(
            buckets=self.buckets, lane=8,
            deadline=AdmissionDeadline(deadline_s))
        # slot rows hold prompt KV + decode headroom; fixed so the decode
        # program compiles exactly once per engine
        self.cache_len = bucket_len(max(self.buckets), self.buckets,
                                    lane=8) + max_decode_len
        # decode-horizon ladder: each fused dispatch runs up to
        # `decode_horizon` on-device decode steps (Model.decode_steps) and
        # ships one (n, B) token block back; powers of two bound the number
        # of compiled horizon programs.  decode_horizon=1 is the measured
        # one-dispatch-per-token baseline (docs/perf.md).
        assert decode_horizon >= 1
        self.decode_horizon = decode_horizon
        self._horizons = [h for h in (1, 2, 4, 8, 16, 32, 64, 128)
                          if h <= decode_horizon] or [1]
        self._queue: List[Request] = []
        self._jit_prefill: Dict = {}
        self._jit_decode_steps: Dict[int, Callable] = {}
        self._jit_insert: Optional[Callable] = None
        self._jit_admit_lane: Optional[Callable] = None
        # decode_steps: on-device scan steps; decode_dispatches: fused jit
        # calls; device_syncs: host<->device round-trips (token-block and
        # first-token fetches) — the quantity the horizon amortizes
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "device_syncs": 0}

        self._param_shardings = None
        self._cache_shardings = None
        self._rep = None
        if plan is not None:
            if plan.param_specs is None:
                plan.param_specs = plan.specs_for_params(
                    jax.eval_shape(lambda: params))
            self._param_shardings = jax.tree.map(plan.sharding,
                                                 plan.param_specs)
            self._rep = plan.sharding(P())
            params = jax.device_put(params, self._param_shardings)
        self.params = params

    # -- public ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = self.policy.bucket_of(len(req.prompt)) + req.max_new_tokens
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: bucket+budget {need} exceeds slot "
                f"cache_len {self.cache_len} (raise max_decode_len)")
        req.t_enqueue = time.perf_counter()
        self._queue.append(req)

    def run(self) -> List[Request]:
        raise NotImplementedError

    # -- jitted programs ------------------------------------------------------

    def _prefill_fn(self, bucket: int, batch: int, cache_slots: int):
        key = (bucket, batch, cache_slots)
        if key not in self._jit_prefill:
            model = self.model

            def fn(params, tokens, positions, lengths):
                caches = model.init_cache(batch, cache_slots)
                logits, caches = model.prefill(
                    params, caches, tokens=tokens, positions=positions,
                    last_idx=lengths - 1)
                return logits, caches

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._param_shardings, self._rep,
                                      self._rep, self._rep)
            self._jit_prefill[key] = jax.jit(fn, **kw)
        return self._jit_prefill[key]

    def _decode_steps_fn(self, n: int):
        """Fused n-step decode program (compiled once per horizon length;
        jax.jit re-specializes per batch shape for the wave engine's
        variable waves)."""
        if n not in self._jit_decode_steps:
            model = self.model

            def fn(params, caches, token, active, eos, budget):
                return model.decode_steps(params, caches, token, active, n,
                                          eos_id=eos, budget=budget,
                                          pad_token=PAD_TOKEN)

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._param_shardings,
                                      self._cache_shardings, self._rep,
                                      self._rep, self._rep, self._rep)
                kw["out_shardings"] = (self._rep, self._rep, self._rep,
                                       self._rep, self._cache_shardings)
            self._jit_decode_steps[n] = jax.jit(fn, donate_argnums=(1,),
                                                **kw)
        return self._jit_decode_steps[n]

    def _admit_lane_fn(self):
        """One fused update of the device decode state for an admission
        (four eager .at[].set dispatches cost ~4x this on small hosts)."""
        if self._jit_admit_lane is None:

            def fn(cur, active, eos, budget, sl, tok, eos_id, bud):
                return (cur.at[sl].set(tok), active.at[sl].set(True),
                        eos.at[sl].set(eos_id), budget.at[sl].set(bud))

            self._jit_admit_lane = jax.jit(fn, donate_argnums=(0, 1, 2, 3))
        return self._jit_admit_lane

    def _pick_horizon(self, waiting: bool, remaining: List[int]) -> int:
        """Adaptive decode horizon from admission pressure.

        With `waiting` requests, aim for the next *predicted* completion
        (min remaining budget) so a slot frees — and is refilled — at the
        earliest useful horizon boundary, floored at 4 steps so dispatch
        overhead stays amortized (a completion can overshoot by at most 3
        masked slot-steps); with a drained queue run up to the longest
        remaining budget.  EOS can still end a lane mid-horizon; those
        lanes decode masked until the boundary (wasted slot-steps, never
        wrong tokens)."""
        if waiting:
            target = max(min(remaining), min(4, self.decode_horizon))
        else:
            target = max(remaining)
        n = 1
        for h in self._horizons:
            if h <= max(1, target):
                n = h
        return n

    def _append_block(self, block: np.ndarray, requests, now: float) -> None:
        """Reconcile one fetched (n, B) token block into request streams.

        -1 marks a lane that was inactive at that step (free slot, or
        early-exited on device after EOS/budget); device-side masking
        mirrors `Request.append_token`'s done rule, so the host simply
        appends until its own done flag flips."""
        for i, r in enumerate(requests):
            if r is None or r.done:
                continue
            for tok in block[:, i]:
                if tok < 0:
                    break
                r.append_token(int(tok), now)
                if r.done:
                    break

    def _prefill_batch(self, wave: List[Request], batch: int,
                       bucket_cache: bool = False):
        """Bucketed left-aligned batched prefill; returns (logits, caches).

        bucket_cache=True writes a bucket-sized cache (the slot engine's
        admission path: `insert_prefill_cache` pads it up to the slot row);
        otherwise the cache has the full cache_len the wave engine decodes
        into directly.
        """
        maxlen = max(len(r.prompt) for r in wave)
        bucket = bucket_len(maxlen, self.buckets, lane=8)
        cache_slots = bucket if bucket_cache else self.cache_len
        toks = np.zeros((batch, bucket), np.int32)
        # pad positions = 2^30 so the causal mask can never attend to them
        # (and cache slot i == position i for decode)
        pos = np.full((batch, bucket), 2 ** 30, np.int32)
        lengths = np.ones((batch,), np.int32)
        for i, r in enumerate(wave):
            n = len(r.prompt)
            toks[i, :n] = r.prompt
            pos[i, :n] = np.arange(n)
            lengths[i] = n
        self.stats["prefill_tokens"] += int(sum(len(r.prompt) for r in wave))
        return self._prefill_fn(bucket, batch, cache_slots)(
            self.params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(lengths))

    def _greedy_next(self, logits) -> np.ndarray:
        self.stats["device_syncs"] += 1
        return np.asarray(jnp.argmax(logits, -1), np.int32)


class ContinuousBatchingEngine(EngineBase):
    """Slot-asynchronous scheduler: admit into freed slots between steps."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.stats.update(admitted=0, completed=0, prefills=0)
        self._slot_caches = None

    # -- internals ------------------------------------------------------------

    def _init_slot_caches(self):
        caches = self.model.init_cache(self.max_batch, self.cache_len)
        if self.plan is not None:
            specs = self.plan.specs_for_caches(
                jax.eval_shape(lambda: caches), batch=self.max_batch,
                slot_table=True)
            self._cache_shardings = jax.tree.map(self.plan.sharding, specs)
            caches = jax.device_put(caches, self._cache_shardings)
        return caches

    def _insert_fn(self):
        if self._jit_insert is None:
            model = self.model

            def fn(big, small, slot):
                return model.insert_prefill_cache(big, small, slot)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._cache_shardings
            self._jit_insert = jax.jit(fn, donate_argnums=(0,), **kw)
        return self._jit_insert

    def _admit(self, req: Request, slot: int, caches):
        """Batch-1 prefill + jitted insert into `slot`; returns (caches, tok).

        The first token comes straight from the prefill logits, so TTFT is
        paid at admission, not at the next decode step.
        """
        logits, small = self._prefill_batch([req], 1, bucket_cache=True)
        caches = self._insert_fn()(caches, small, slot)
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        return caches, int(self._greedy_next(logits)[0])

    # -- scheduler loop -------------------------------------------------------

    def run(self) -> List[Request]:
        """Serve until queue + slots drain; returns requests sorted by rid.

        Admission honours `Request.t_arrival` (seconds after this call), so
        a Poisson stream can be replayed by submitting everything up front.
        """
        if self._slot_caches is None:
            self._slot_caches = self._init_slot_caches()
        caches = self._slot_caches
        # decode/insert donate the cache buffers: until the loop finishes,
        # self._slot_caches may reference deleted arrays.  Drop the handle
        # so an abnormal exit (interrupt, OOM) re-allocates on the next run
        # instead of poisoning the engine; restored on normal completion.
        self._slot_caches = None
        done: List[Request] = []
        pending = self._queue
        self._queue = []
        slots: List[Optional[Request]] = [None] * self.max_batch
        # decode state lives on device between horizon boundaries; the host
        # only touches it on admission events (completions deactivate their
        # lane on device, inside the fused loop)
        cur = jnp.full((self.max_batch,), PAD_TOKEN, jnp.int32)
        active = jnp.zeros((self.max_batch,), bool)
        eos = jnp.full((self.max_batch,), -1, jnp.int32)
        budget = jnp.zeros((self.max_batch,), jnp.int32)
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)

        while pending or any(r is not None for r in slots):
            now = time.perf_counter() - t0
            free = [i for i, r in enumerate(slots) if r is None]
            arrived = [r for r in pending if r.t_arrival <= now]
            if free and arrived:
                pick = self.policy.select(
                    arrived, len(free),
                    warm=[b for (b, n, _) in self._jit_prefill if n == 1],
                    now=now)
                for r in [arrived[p] for p in pick]:
                    pending.remove(r)
                    sl = free.pop(0)
                    caches, tok = self._admit(r, sl, caches)
                    t_now = time.perf_counter()
                    r.t_admitted = t_now
                    r.append_token(tok, t_now)
                    if r.done:  # budget of 1 or instant EOS: slot stays free
                        done.append(r)
                        free.insert(0, sl)
                        self.stats["completed"] += 1
                    else:
                        slots[sl] = r
                        cur, active, eos, budget = self._admit_lane_fn()(
                            cur, active, eos, budget, sl, tok, r.eos_id,
                            r.max_new_tokens - len(r.tokens_out))
            if not any(r is not None for r in slots):
                if pending:  # idle until the next arrival
                    wait = min(r.t_arrival for r in pending) \
                        - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.005))
                continue

            n = self._pick_horizon(
                bool(pending),
                [r.max_new_tokens - len(r.tokens_out)
                 for r in slots if r is not None])
            t_step = time.perf_counter()
            toks, cur, active, budget, caches = self._decode_steps_fn(n)(
                self.params, caches, cur, active, eos, budget)
            block = np.asarray(toks)  # the only per-dispatch device sync
            self.stats["decode_dispatches"] += 1
            self.stats["decode_steps"] += n
            self.stats["device_syncs"] += 1
            if self.monitor is not None:
                self.monitor.observe(self.stats["decode_steps"],
                                     (time.perf_counter() - t_step) / n)
            self._append_block(block, slots, time.perf_counter())
            for i, r in enumerate(slots):
                if r is not None and r.done:
                    done.append(r)
                    slots[i] = None  # device lane already inactive
                    self.stats["completed"] += 1

        self._slot_caches = caches
        return sorted(done, key=lambda r: r.rid)


class WaveEngine(EngineBase):
    """The seed's batch-synchronous scheduler, kept as the measured baseline.

    One batched prefill per wave, decode until every member finishes.  The
    seed's dead deadline loop is gone (the deadline governs admission order
    in the continuous engine instead), and finished rows feed PAD_TOKEN —
    their cache rows are frozen by the decode active mask rather than
    absorbing stale writes.
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.stats.update(waves=0)

    def run(self) -> List[Request]:
        done: List[Request] = []
        pending = self._queue
        self._queue = []
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)
        deadline_s = self.policy.deadline.deadline_s
        while pending:
            # deadline batching: launch a partial wave at the deadline with
            # whatever requests arrived, instead of waiting for a full batch
            while True:
                now = time.perf_counter() - t0
                arrived = [r for r in pending if r.t_arrival <= now]
                if len(arrived) >= self.max_batch:
                    break
                if len(arrived) == len(pending):
                    break  # nobody else can join: don't sit out the deadline
                if arrived and now - min(
                        r.t_arrival for r in arrived) >= deadline_s:
                    break
                nxt = min((r.t_arrival for r in pending
                           if r.t_arrival > now), default=float("inf"))
                wake = min([nxt] + [r.t_arrival + deadline_s
                                    for r in arrived])
                time.sleep(max(min(wake - now, 0.005), 0.0005))
            wave = arrived[: self.max_batch]
            for r in wave:
                pending.remove(r)
            done += self._serve_wave(wave)
        return done

    def _serve_wave(self, wave: List[Request]) -> List[Request]:
        self.stats["waves"] += 1
        b = len(wave)
        logits, caches = self._prefill_batch(wave, b)
        nxt = self._greedy_next(logits)
        now = time.perf_counter()
        for i, r in enumerate(wave):
            r.append_token(int(nxt[i]), now)
        # decode state moves to device once per wave; the fused horizon
        # loop feeds tokens back on device and ships (n, b) blocks out
        cur = jnp.asarray([PAD_TOKEN if r.done else r.tokens_out[-1]
                           for r in wave], jnp.int32)
        active = jnp.asarray([not r.done for r in wave])
        eos = jnp.asarray([r.eos_id for r in wave], jnp.int32)
        budget = jnp.asarray([r.max_new_tokens - len(r.tokens_out)
                              for r in wave], jnp.int32)

        while not all(r.done for r in wave):
            n = self._pick_horizon(
                False, [r.max_new_tokens - len(r.tokens_out)
                        for r in wave if not r.done])
            t_step = time.perf_counter()
            toks, cur, active, budget, caches = self._decode_steps_fn(n)(
                self.params, caches, cur, active, eos, budget)
            block = np.asarray(toks)
            self.stats["decode_dispatches"] += 1
            self.stats["decode_steps"] += n
            self.stats["device_syncs"] += 1
            if self.monitor is not None:
                self.monitor.observe(self.stats["decode_steps"],
                                     (time.perf_counter() - t_step) / n)
            self._append_block(block, wave, time.perf_counter())
        return wave


# the slot-based continuous-batching engine is the serving default
ServingEngine = ContinuousBatchingEngine
