"""WaveEngine: the seed's batch-synchronous scheduler, kept as the
measured baseline for `benchmarks/run.py serve_cb` — one batched prefill
per wave, decode until every member finishes, finished rows feeding
PAD_TOKEN behind the decode active mask.  Split out of engine.py so the
composition root stays thin; re-exported there for the public API.
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

# engine.py imports this module at its bottom, after EngineBase exists, so
# the circular import resolves in definition order
from repro.serving import engine as _engine
from repro.serving.executor import PAD_TOKEN
from repro.serving.scheduler import Request


class WaveEngine(_engine.EngineBase):
    """Batch-synchronous baseline (docs/serving.md §wave baseline)."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        if self.plan is not None and self.plan.mode == "serve_pipeline":
            raise ValueError(
                "serve_pipeline drives the continuous engine's fixed-lane "
                "decode state; the wave baseline has no fixed batch")
        self.stats.update(waves=0)

    def run(self) -> List[Request]:
        done: List[Request] = []
        pending = self.sched.take_queue()
        t0 = time.perf_counter()
        for r in pending:  # latency clocks start at simulated arrival
            r.t_enqueue = max(r.t_enqueue, t0 + r.t_arrival)
        deadline_s = self.policy.deadline.deadline_s
        while pending:
            # deadline batching: launch a partial wave at the deadline
            # instead of waiting for a full batch
            while True:
                now = time.perf_counter() - t0
                arrived = [r for r in pending if r.t_arrival <= now]
                if len(arrived) >= self.max_batch:
                    break
                if len(arrived) == len(pending):
                    break  # nobody else can join: don't sit out the deadline
                if arrived and now - min(
                        r.t_arrival for r in arrived) >= deadline_s:
                    break
                nxt = min((r.t_arrival for r in pending
                           if r.t_arrival > now), default=float("inf"))
                wake = min([nxt] + [r.t_arrival + deadline_s
                                    for r in arrived])
                time.sleep(max(min(wake - now, 0.005), 0.0005))
            wave = arrived[: self.max_batch]
            for r in wave:
                pending.remove(r)
            done += self._serve_wave(wave)
        return done

    def _serve_wave(self, wave: List[Request]) -> List[Request]:
        self.stats["waves"] += 1
        logits, caches = self._prefill([r.prompt for r in wave], len(wave))
        nxt = self._greedy_next(logits)
        now = time.perf_counter()
        for i, r in enumerate(wave):
            r.append_token(int(nxt[i]), now)
        # decode state moves to device once per wave; the fused horizon
        # loop feeds tokens back on device and ships (n, b) blocks out
        st = {"caches": caches,
              "cur": jnp.asarray([PAD_TOKEN if r.done else r.tokens_out[-1]
                                  for r in wave], jnp.int32),
              "active": jnp.asarray([not r.done for r in wave]),
              "eos": jnp.asarray([r.eos_id for r in wave], jnp.int32),
              "budget": jnp.asarray([r.remaining() for r in wave],
                                    jnp.int32)}

        while not all(r.done for r in wave):
            n = self.sched.pick_horizon(
                False, [r.remaining() for r in wave if not r.done])
            t_step = time.perf_counter()
            toks = self.executor.decode(st, n, paged=False)
            self.stats["decode_dispatches"] += 1
            self.stats["decode_steps"] += n
            self.stats["device_syncs"] += 1
            if self.monitor is not None:
                self.monitor.observe(self.stats["decode_steps"],
                                     (time.perf_counter() - t_step) / n)
            self.sched.append_block(np.asarray(toks), wave,
                                    time.perf_counter())
        return wave
