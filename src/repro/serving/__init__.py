"""Serving package: engine (composition root) + scheduler / executor /
kv_manager layers.  The re-export is lazy (PEP 562) so the host-side
layers (scheduler, kv_manager) stay importable without pulling jax."""

_ENGINE_API = ("ContinuousBatchingEngine", "EngineBase", "Request",
               "ServingEngine", "WaveEngine")


def __getattr__(name):
    if name in _ENGINE_API:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(name)
