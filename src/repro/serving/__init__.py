from repro.serving.engine import (  # noqa: F401
    ContinuousBatchingEngine, EngineBase, Request, ServingEngine, WaveEngine,
)
