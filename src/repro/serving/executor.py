"""Device-side serving execution: every jitted program, in one place.

The executor owns params (quantizing them for W8A8 serving when asked),
applies the Cluster-Builder plan (`jax.device_put` placement +
`in_shardings`/`out_shardings` on every program, so donated cache updates
never migrate), and compiles/caches the serving programs: bucketed
prefill, the fused decode-horizon loop (`Model.decode_steps`), the
slot/lane admission updates, and — under a `mode="serve_pipeline"` plan —
the stage-pipelined decode program that streams micro-steps through the
mesh with `collective_permute` (the TPU analogue of the paper's six-FPGA
pipelined encoder).

Plan-exactness contract: under a `mode="serve"` plan every program is
traced inside a `shard_hints.hints(serve_exact=True)` context, which (a)
forces activation gathers before the plan's replicated reduction
projections (gather-form TP — Fig. 14's gather-then-linear_o) and (b)
routes the paged decode kernels through shard_map with the arena's
kv-head dim partitioned (kernels/ops.py).  Every cross-device op is then
either a gather or per-head-local math, so sharded token streams are
bit-identical to single-device serving (tests/test_sharded_serving.py).

Throughput mode (`plan.exact=False`): serve plans trace under
`hints(serve_psum=True)` instead — column-sharded reduction projections
with one all-reduce each (Megatron form), and serve_pipeline plans swap
the drained GPipe decode program for the request-skewed schedule
(`_pipeline_skew_decode_fn`).  Streams then satisfy the token-match band
rather than bitwise equality (docs/serving.md §exactness contract).

Host-side policy lives in serving/scheduler.py; page accounting in
serving/kv_manager.py; serving/engine.py composes the three.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.packing import bucket_len
from repro.models import shard_hints
from repro.models.transformer import (
    Model, greedy_token_update, layer_plan,
)

PAD_TOKEN = 0  # fed for finished/free slot rows; their logits are never read


class Executor:
    """Jit-program cache + plan placement for one serving engine."""

    def __init__(self, model: Model, params, plan=None,
                 quant_weights: bool = False, max_batch: int = 8,
                 cache_len: int = 0, buckets=()):
        self.model = model
        self.plan = plan
        self.quant_weights = bool(quant_weights)
        self.max_batch = max_batch
        self.cache_len = cache_len  # engine re-rounds it for paged mode
        self.buckets = tuple(sorted(buckets))
        if self.quant_weights:
            # int8 weight path (models/quantized.py): projections/MLP run
            # W8A8; with kv_dtype="int8" on top the decode loop is
            # integer-dominant — the paper's I-BERT datapath at scale
            from repro.models.quantized import quantize_params_for_serving
            params = quantize_params_for_serving(params)
        self._param_shardings = None
        self._cache_shardings = None
        self._rep = None
        self._hints_kw = None
        if plan is not None:
            # param specs derive from the leaf tree actually served: under
            # quant_weights that is the quantized tree — the rule table
            # shards each "q" like its parent weight and replicates "s" —
            # which is what lets W8A8 compose with a ClusterPlan
            plan.param_specs = plan.specs_for_params(
                jax.eval_shape(lambda: params))
            self._param_shardings = jax.tree.map(plan.sharding,
                                                 plan.param_specs)
            self._rep = plan.sharding(P())
            params = jax.device_put(params, self._param_shardings)
            if plan.mode == "serve":
                ex = getattr(plan, "exact", True)
                self._hints_kw = dict(mesh=plan.mesh, dp_axes=plan.axes.dp,
                                      tp_axis=plan.axes.tp, serve_exact=ex,
                                      serve_psum=not ex)
        self.params = params
        self._jit_prefill: Dict = {}
        self._jit_decode: Dict = {}
        self._jit_insert = None
        self._jit_admit_lane = None
        self._jit_admit_cold: Dict = {}
        self._jit_admit_hit = None
        self._jit_admit_lane_paged = None
        self._jit_park = None
        # speculative decoding (set_draft): the draft model's params and
        # program caches — spec programs are keyed per ladder depth like
        # the horizon programs
        self.draft_model = None
        self.draft_params = None
        self._jit_spec: Dict = {}
        self._jit_draft_prefill: Dict = {}
        self._jit_admit_cold_draft: Dict = {}
        self._jit_catchup: Dict = {}
        # disaggregated prefill/decode pools (set_disagg): a second param
        # copy + mesh for the prefill pool, and the page-shipping programs
        self.prefill_params = None
        self.prefill_mesh = None
        self.decode_mesh = None
        self._prefill_sharding = None
        self._decode_sharding = None
        self._jit_prefill_admit: Dict = {}
        self._jit_ship: Dict = {}

    def set_disagg(self, prefill_devs, decode_devs) -> None:
        """Split the executor across disjoint device pools: prefill
        programs (and the staging arena they scatter into) live on
        ``prefill_devs``, the decode arena / lane state / decode-side
        admission programs on ``decode_devs``.  Params are committed to
        both pools — prefill reads ``prefill_params``, everything else the
        decode copy — so every program's placement follows its committed
        operands and the only cross-pool traffic is `ship_pages`'
        explicit block transfer.  Composes with plan=None engines only
        (a ClusterPlan already owns placement)."""
        assert self.plan is None, \
            "disagg needs plan=None (a plan already owns placement)"
        from jax.sharding import NamedSharding

        from repro.serving.replica import make_group_mesh
        self.prefill_mesh = make_group_mesh(
            prefill_devs, (len(prefill_devs),), ("pool",))
        self.decode_mesh = make_group_mesh(
            decode_devs, (len(decode_devs),), ("pool",))
        self._prefill_sharding = NamedSharding(self.prefill_mesh, P())
        self._decode_sharding = NamedSharding(self.decode_mesh, P())
        self.prefill_params = jax.device_put(self.params,
                                             self._prefill_sharding)
        self.params = jax.device_put(self.params, self._decode_sharding)

    def set_draft(self, draft_model: Model, draft_params) -> None:
        """Install the speculative-decoding draft model.  Draft weights
        ride the same quantization switch as the target; under a plan they
        are *replicated* (a reduced-class draft is far below the sharding
        payoff point, and replication keeps the draft scan free of
        collectives so only the verify pass pays TP gathers)."""
        if self.quant_weights:
            from repro.models.quantized import quantize_params_for_serving
            draft_params = quantize_params_for_serving(draft_params)
        if self.plan is not None:
            draft_params = jax.device_put(draft_params, self._rep)
        self.draft_model = draft_model
        self.draft_params = draft_params

    def init_draft_caches(self, page_size: int, num_pages: int,
                          max_pages: int, kv_dtype: str = "bf16"):
        """The draft model's paged arena — replicated under a plan, like
        its params (same rationale)."""
        caches = self.draft_model.init_paged_cache(
            self.max_batch, num_pages, page_size, max_pages,
            kv_dtype=kv_dtype)
        if self.plan is not None:
            caches = jax.device_put(caches, self._rep)
        return caches

    # -- trace context --------------------------------------------------------

    @contextlib.contextmanager
    def _ctx(self):
        """serve_exact hints are read at trace time, so every jitted call
        goes through here; re-entering an already-traced program costs one
        threadlocal write."""
        if self._hints_kw is None:
            yield
        else:
            with shard_hints.hints(**self._hints_kw):
                yield

    def _call(self, fn, *args):
        with self._ctx():
            return fn(*args)

    # -- cache construction / placement ---------------------------------------

    def init_caches(self, paged: bool, page_size: int = 0,
                    num_pages: int = 0, max_pages: int = 0,
                    kv_dtype: str = "bf16"):
        """Build the persistent serving cache and place it under the plan
        (paged arenas: kv-head-sharded; dense slot tables: serve-mode slot
        specs; serve_pipeline: stage-sharded scan leaves)."""
        if paged:
            caches = self.model.init_paged_cache(
                self.max_batch, num_pages, page_size, max_pages,
                kv_dtype=kv_dtype)
        else:
            caches = self.model.init_cache(self.max_batch, self.cache_len)
        if self.plan is not None:
            specs = self.plan.specs_for_caches(
                jax.eval_shape(lambda: caches), batch=self.max_batch,
                slot_table=True, paged=paged)
            self._cache_shardings = jax.tree.map(self.plan.sharding, specs)
            caches = jax.device_put(caches, self._cache_shardings)
        elif self._decode_sharding is not None:
            caches = jax.device_put(caches, self._decode_sharding)
        return caches

    def fresh_state(self, caches, paged: bool,
                    draft_caches=None) -> Dict[str, Any]:
        """Device decode state: mutated only through the programs below,
        fetched only as (n, B) token blocks at horizon boundaries."""
        b = self.max_batch
        st = {"caches": caches,
              "cur": jnp.full((b,), PAD_TOKEN, jnp.int32),
              "active": jnp.zeros((b,), bool),
              "eos": jnp.full((b,), -1, jnp.int32),
              "budget": jnp.zeros((b,), jnp.int32)}
        if paged:
            st.update(forced=jnp.zeros((b, self.cache_len), jnp.int32),
                      flen=jnp.zeros((b,), jnp.int32),
                      fptr=jnp.zeros((b,), jnp.int32))
        if draft_caches is not None:
            st["draft_caches"] = draft_caches
        if self._decode_sharding is not None:
            st = jax.device_put(st, self._decode_sharding)
        return st

    # -- prefill ---------------------------------------------------------------

    def _prefill_fn(self, bucket: int, batch: int, cache_slots: int):
        key = (bucket, batch, cache_slots)
        if key not in self._jit_prefill:
            model = self.model

            def fn(params, tokens, positions, lengths):
                caches = model.init_cache(batch, cache_slots)
                logits, caches = model.prefill(
                    params, caches, tokens=tokens, positions=positions,
                    last_idx=lengths - 1)
                return logits, caches

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._param_shardings, self._rep,
                                      self._rep, self._rep)
            self._jit_prefill[key] = jax.jit(fn, **kw)
        return self._jit_prefill[key]

    def prefill_prompts(self, prompts, batch: int,
                        bucket_cache: bool = False):
        """Bucketed left-aligned batched prefill; returns (logits, caches).

        bucket_cache=True writes a bucket-sized cache (the slot engine's
        admission path pads it up to the slot row on insert); otherwise
        the cache spans cache_len and is decoded into directly (waves).
        """
        maxlen = max(len(p) for p in prompts)
        bucket = bucket_len(maxlen, self.buckets, lane=8)
        cache_slots = bucket if bucket_cache else self.cache_len
        toks = np.zeros((batch, bucket), np.int32)
        # pad positions = 2^30 so the causal mask can never attend to them
        # (and cache slot i == position i for decode)
        pos = np.full((batch, bucket), 2 ** 30, np.int32)
        lengths = np.ones((batch,), np.int32)
        for i, p in enumerate(prompts):
            n = len(p)
            toks[i, :n] = p
            pos[i, :n] = np.arange(n)
            lengths[i] = n
        # under disagg every prefill belongs to the prefill pool: the
        # pool-committed param copy pins the dispatch there
        params = (self.prefill_params if self.prefill_params is not None
                  else self.params)
        return self._call(self._prefill_fn(bucket, batch, cache_slots),
                          params, jnp.asarray(toks), jnp.asarray(pos),
                          jnp.asarray(lengths))

    @property
    def warm_buckets(self):
        return [b for (b, n, _) in self._jit_prefill if n == 1]

    # -- fused decode ----------------------------------------------------------

    def decode_fn(self, n: int, paged: bool):
        """Fused n-step decode program (compiled once per horizon length;
        jax.jit re-specializes per batch shape for the wave engine's
        variable waves).  The paged variant threads the forced-token queue
        (prefix-hit suffix ingest) through the same fused loop; a
        serve_pipeline plan swaps in the stage-streaming program."""
        key = (n, paged)
        if key in self._jit_decode:
            return self._jit_decode[key]
        model = self.model
        if self.plan is not None and self.plan.mode == "serve_pipeline":
            if getattr(self.plan, "exact", True):
                assert not paged, \
                    "exact serve_pipeline streams the dense slot path"
                self._jit_decode[key] = self._pipeline_decode_fn(n)
            else:
                assert paged, \
                    "throughput serve_pipeline runs the paged arena path"
                self._jit_decode[key] = self._pipeline_skew_decode_fn(n)
        elif paged:

            def pfn(params, caches, token, active, eos, budget,
                    forced, flen, fptr):
                return model.decode_steps(
                    params, caches, token, active, n, eos_id=eos,
                    budget=budget, pad_token=PAD_TOKEN, forced=forced,
                    forced_len=flen, forced_ptr=fptr)

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = ((self._param_shardings,
                                       self._cache_shardings)
                                      + (self._rep,) * 7)
                kw["out_shardings"] = ((self._rep,) * 5
                                       + (self._cache_shardings,))
            self._jit_decode[key] = jax.jit(pfn, donate_argnums=(1,), **kw)
        else:

            def fn(params, caches, token, active, eos, budget):
                return model.decode_steps(params, caches, token, active, n,
                                          eos_id=eos, budget=budget,
                                          pad_token=PAD_TOKEN)

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = ((self._param_shardings,
                                       self._cache_shardings)
                                      + (self._rep,) * 4)
                kw["out_shardings"] = ((self._rep,) * 4
                                       + (self._cache_shardings,))
            self._jit_decode[key] = jax.jit(fn, donate_argnums=(1,), **kw)
        return self._jit_decode[key]

    def decode(self, st: Dict[str, Any], n: int, paged: bool):
        """Run one fused dispatch against the state dict; returns the
        (n, B) token block, st updated in place."""
        fn = self.decode_fn(n, paged)
        if paged:
            toks, cur, active, budget, fptr, caches = self._call(
                fn, self.params, st["caches"], st["cur"], st["active"],
                st["eos"], st["budget"], st["forced"], st["flen"],
                st["fptr"])
            st.update(caches=caches, cur=cur, active=active, budget=budget,
                      fptr=fptr)
        else:
            toks, cur, active, budget, caches = self._call(
                fn, self.params, st["caches"], st["cur"], st["active"],
                st["eos"], st["budget"])
            st.update(caches=caches, cur=cur, active=active, budget=budget)
        return toks

    def warm_ladder(self, st: Dict[str, Any], horizons) -> None:
        """Compile the whole horizon ladder + paged lane-state programs by
        executing them on the empty (all-inactive) state — semantically a
        no-op, but a compile that instead fired mid-serving would stall
        every resident lane.  The radix tree makes the horizon schedule
        state-dependent, so "the warmup pass saw it" does not cover later
        passes the way it does for dense slots."""
        for n in horizons:
            self.decode(st, n, paged=True)
        trash = np.zeros((st["caches"]["pt"].shape[1],), np.int32)
        self.admit_hit(st, 0, trash, 0, trash)
        self.admit_lane_paged(st, 0, PAD_TOKEN, -1, 0,
                              np.zeros((0,), np.int32), 0)
        self.park_lane(st, 0)

    # -- speculative decoding --------------------------------------------------

    def spec_fn(self, k: int):
        """Fused speculative program for draft depth `k`: the k+1-step
        draft scan, the single batched target verify (Sq = k+1 through the
        paged multi-query branch), and the acceptance/emission state
        machine — one dispatch, one host fetch, up to k+1 tokens per lane.
        Replaces the horizon decode program for spec-mode dispatches and
        handles the forced-token queue itself, so admissions need no extra
        programs."""
        if k in self._jit_spec:
            return self._jit_spec[k]
        model, draft = self.model, self.draft_model

        def fn(params, dparams, caches, dcaches, token, active, eos,
               budget, forced, flen, fptr):
            toks, cur, act, rem, fptr, caches, dcaches, _ = \
                model.spec_decode_step(
                    params, caches, token, active, k, draft, dparams,
                    dcaches, eos_id=eos, budget=budget, pad_token=PAD_TOKEN,
                    forced=forced, forced_len=flen, forced_ptr=fptr)
            return toks, cur, act, rem, fptr, caches, dcaches

        kw = {}
        if self.plan is not None:
            kw["in_shardings"] = ((self._param_shardings, self._rep,
                                   self._cache_shardings, self._rep)
                                  + (self._rep,) * 7)
            kw["out_shardings"] = ((self._rep,) * 5
                                   + (self._cache_shardings, self._rep))
        self._jit_spec[k] = jax.jit(fn, donate_argnums=(2, 3), **kw)
        return self._jit_spec[k]

    def spec_decode(self, st: Dict[str, Any], k: int):
        """One speculative dispatch; returns the (k+1, B) token block,
        st (both cache trees included) updated in place."""
        toks, cur, active, budget, fptr, caches, dcaches = self._call(
            self.spec_fn(k), self.params, self.draft_params, st["caches"],
            st["draft_caches"], st["cur"], st["active"], st["eos"],
            st["budget"], st["forced"], st["flen"], st["fptr"])
        st.update(caches=caches, draft_caches=dcaches, cur=cur,
                  active=active, budget=budget, fptr=fptr)
        return toks

    def warm_spec(self, st: Dict[str, Any], ladder) -> None:
        """Compile the spec ladder on the empty state (same rationale as
        warm_ladder)."""
        for k in ladder:
            self.spec_decode(st, k)

    def draft_prefill_prompts(self, prompts, batch: int):
        """Bucketed batch-1 prefill on the *draft* model (cold draft-lane
        admission; a prefix-hit lane prefills prompt[:hit_len] — the draft
        has no radix tree, but hit lengths are page-aligned so the suffix
        ingests in lockstep through the spec program's forced queue)."""
        from repro.core.packing import bucket_len as _bl
        maxlen = max(len(p) for p in prompts)
        bucket = _bl(maxlen, self.buckets, lane=8)
        key = (bucket, batch)
        if key not in self._jit_draft_prefill:
            draft = self.draft_model

            def fn(dparams, tokens, positions, lengths):
                caches = draft.init_cache(batch, bucket)
                return draft.prefill(dparams, caches, tokens=tokens,
                                     positions=positions,
                                     last_idx=lengths - 1)

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._rep,) * 4
            self._jit_draft_prefill[key] = jax.jit(fn, **kw)
        toks = np.zeros((batch, bucket), np.int32)
        pos = np.full((batch, bucket), 2 ** 30, np.int32)
        lengths = np.ones((batch,), np.int32)
        for i, p in enumerate(prompts):
            n = len(p)
            toks[i, :n] = p
            pos[i, :n] = np.arange(n)
            lengths[i] = n
        _, caches = self._call(self._jit_draft_prefill[key],
                               self.draft_params, jnp.asarray(toks),
                               jnp.asarray(pos), jnp.asarray(lengths))
        return caches, bucket

    def admit_cold_draft(self, st, slot: int, small, pt_row, pos0: int,
                         reset, write_pages: np.ndarray,
                         bucket: int) -> None:
        """Scatter a draft bucket prefill into the lane's draft-arena
        pages (the draft twin of admit_cold)."""
        key = (bucket, len(write_pages))
        if key not in self._jit_admit_cold_draft:
            draft = self.draft_model

            def fn(big, small, slot, pt_row, pos0, reset, wp):
                return draft.admit_lane_cache(big, slot, pt_row, pos0,
                                              reset, small=small,
                                              write_pages=wp)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._rep
            self._jit_admit_cold_draft[key] = jax.jit(
                fn, donate_argnums=(0,), **kw)
        st["draft_caches"] = self._call(
            self._jit_admit_cold_draft[key], st["draft_caches"], small,
            slot, jnp.asarray(pt_row), pos0, jnp.asarray(reset),
            jnp.asarray(write_pages))

    def draft_catchup(self, st, tokens: np.ndarray,
                      lag: np.ndarray) -> None:
        """Re-synchronize draft lanes after spec-disabled dispatches: feed
        each lane the stream tokens the target consumed while the draft
        sat idle (tokens[b, :lag[b]]; columns past a lane's lag are
        masked, so its cache rows and position counter stop advancing at
        exactly the target's position).  Compiled per power-of-two width
        like the horizon ladder."""
        n = max(1, int(tokens.shape[1]))
        n_pad = 1 << (n - 1).bit_length()
        if n_pad not in self._jit_catchup:
            draft = self.draft_model

            def fn(dparams, dcaches, toks, lag):
                def step(caches, xs):
                    tok, j = xs
                    live = j < lag
                    _, caches = draft.decode_step(dparams, caches, tok,
                                                  active=live)
                    return caches, None

                dcaches, _ = jax.lax.scan(
                    step, dcaches,
                    (toks.T, jnp.arange(n_pad, dtype=jnp.int32)))
                return dcaches

            kw = {}
            if self.plan is not None:
                kw["in_shardings"] = (self._rep,) * 4
                kw["out_shardings"] = self._rep
            self._jit_catchup[n_pad] = jax.jit(fn, donate_argnums=(1,),
                                               **kw)
        padded = np.zeros((tokens.shape[0], n_pad), np.int32)
        padded[:, :tokens.shape[1]] = tokens
        st["draft_caches"] = self._call(
            self._jit_catchup[n_pad], self.draft_params,
            st["draft_caches"], jnp.asarray(padded),
            jnp.asarray(lag, np.int32))

    # -- slot / lane updates ---------------------------------------------------

    def insert(self, big, small, slot: int):
        """Write a batch-1 prefill cache into a dense slot row."""
        if self._jit_insert is None:
            model = self.model

            def fn(big, small, slot):
                return model.insert_prefill_cache(big, small, slot)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._cache_shardings
            self._jit_insert = jax.jit(fn, donate_argnums=(0,), **kw)
        return self._call(self._jit_insert, big, small, slot)

    def admit_hit(self, st, slot: int, pt_row, pos0: int, reset) -> None:
        """Point a lane at its (shared prefix + own) pages; the suffix
        arrives later through the decode loop's forced queue."""
        if self._jit_admit_hit is None:
            model = self.model

            def fn(big, slot, pt_row, pos0, reset):
                return model.admit_lane_cache(big, slot, pt_row, pos0, reset)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._cache_shardings
            self._jit_admit_hit = jax.jit(fn, donate_argnums=(0,), **kw)
        st["caches"] = self._call(self._jit_admit_hit, st["caches"], slot,
                                  jnp.asarray(pt_row), pos0,
                                  jnp.asarray(reset))

    def admit_cold(self, st, slot: int, small, pt_row, pos0: int, reset,
                   write_pages: np.ndarray, bucket: int) -> None:
        """Scatter a bucket prefill cache into the lane's arena pages."""
        key = (bucket, len(write_pages))
        if key not in self._jit_admit_cold:
            model = self.model

            def fn(big, small, slot, pt_row, pos0, reset, wp):
                return model.admit_lane_cache(big, slot, pt_row, pos0,
                                              reset, small=small,
                                              write_pages=wp)

            kw = {}
            if self.plan is not None:
                kw["out_shardings"] = self._cache_shardings
            self._jit_admit_cold[key] = jax.jit(fn, donate_argnums=(0,),
                                                **kw)
        st["caches"] = self._call(
            self._jit_admit_cold[key], st["caches"], small, slot,
            jnp.asarray(pt_row), pos0, jnp.asarray(reset),
            jnp.asarray(write_pages))

    # -- disaggregated page shipping (set_disagg) ------------------------------

    def init_prefill_arena(self, page_size: int, num_pages: int,
                           max_pages: int, kv_dtype: str = "bf16"):
        """The prefill pool's staging arena: a batch-1 paged cache on the
        prefill mesh.  One admission stages at a time (lane 0), so
        ``num_pages = max_pages + 1`` (+ trash page) always covers it."""
        arena = self.model.init_paged_cache(
            1, num_pages, page_size, max_pages, kv_dtype=kv_dtype)
        return jax.device_put(arena, self._prefill_sharding)

    def prefill_admit(self, arena, small, pt_row, pos0: int, reset,
                      write_pages: np.ndarray, bucket: int):
        """Scatter a bucket prefill cache into the staging arena's pages
        (admit_cold's scatter, aimed at the prefill pool's arena at lane
        0).  The page *contents* this writes are exactly what admit_cold
        would have written into the decode arena — int8 arenas quantize on
        the way in here, before shipping — which is what makes disagg
        streams bit-identical to colocated serving."""
        key = (bucket, len(write_pages))
        if key not in self._jit_prefill_admit:
            model = self.model

            def fn(big, small, pt_row, pos0, reset, wp):
                return model.admit_lane_cache(big, 0, pt_row, pos0, reset,
                                              small=small, write_pages=wp)

            self._jit_prefill_admit[key] = jax.jit(fn, donate_argnums=(0,))
        return self._call(self._jit_prefill_admit[key], arena, small,
                          jnp.asarray(pt_row), pos0, jnp.asarray(reset),
                          jnp.asarray(write_pages))

    def ship_pages(self, arena, st, src_pages, dst_pages) -> None:
        """Ship completed KV pages from the prefill arena into the decode
        arena: ONE batched gather on the prefill mesh, one cross-pool
        block transfer, one batched scatter (donated) into the decode
        caches.  Every arena leaf rides the same tree map, so an int8
        arena's `k_scale`/`v_scale` planes travel with their pages.

        Programs are keyed per power-of-two page count; both index
        vectors pad with the trash page 0, which is safe on each side —
        trash-page kpos is sentinel by construction (inactive-lane writes
        are sentinel-stamped, attention.py), so a trash→trash copy cannot
        make stale keys reachable."""
        n = len(src_pages)
        assert n == len(dst_pages) and n > 0
        n_pad = 1 << (n - 1).bit_length()
        src = np.zeros((n_pad,), np.int32)
        src[:n] = src_pages
        dst = np.zeros((n_pad,), np.int32)
        dst[:n] = dst_pages
        if n_pad not in self._jit_ship:
            from repro.models.transformer import paged_cache_map

            def gfn(scan, tail, idx):
                return paged_cache_map(
                    lambda ax, name, b: jnp.take(b, idx, axis=ax),
                    {"scan": scan, "tail": tail})

            def sfn(caches, blk, idx):
                sub = paged_cache_map(
                    lambda ax, name, b, s: (b.at[idx].set(s) if ax == 0
                                            else b.at[:, idx].set(s)),
                    {"scan": caches["scan"], "tail": caches["tail"]}, blk)
                return dict(caches, scan=sub["scan"], tail=sub["tail"])

            self._jit_ship[n_pad] = (jax.jit(gfn),
                                     jax.jit(sfn, donate_argnums=(0,)))
        gather, scatter = self._jit_ship[n_pad]
        blk = gather(arena["scan"], arena["tail"], jnp.asarray(src))
        blk = jax.device_put(blk, self._decode_sharding)  # the pool hop
        st["caches"] = scatter(st["caches"], blk, jnp.asarray(dst))

    def admit_lane(self, st, sl: int, tok: int, eos_id: int,
                   bud: int) -> None:
        """One fused update of the device decode state for an admission
        (four eager .at[].set dispatches cost ~4x this on small hosts)."""
        if self._jit_admit_lane is None:

            def fn(cur, active, eos, budget, sl, tok, eos_id, bud):
                return (cur.at[sl].set(tok), active.at[sl].set(True),
                        eos.at[sl].set(eos_id), budget.at[sl].set(bud))

            self._jit_admit_lane = jax.jit(fn, donate_argnums=(0, 1, 2, 3))
        st["cur"], st["active"], st["eos"], st["budget"] = self._call(
            self._jit_admit_lane, st["cur"], st["active"], st["eos"],
            st["budget"], sl, tok, eos_id, bud)

    def admit_lane_paged(self, st, sl: int, tok: int, eos_id: int, bud: int,
                         forced_rest, flen: int) -> None:
        """Fused lane-state update for a paged admission: decode state plus
        the forced-token (suffix-ingest) queue row."""
        if self._jit_admit_lane_paged is None:

            def fn(cur, active, eos, budget, forced, fl_, fptr, sl, tok,
                   eos_id, bud, frow, fl):
                return (cur.at[sl].set(tok), active.at[sl].set(True),
                        eos.at[sl].set(eos_id), budget.at[sl].set(bud),
                        forced.at[sl].set(frow), fl_.at[sl].set(fl),
                        fptr.at[sl].set(0))

            self._jit_admit_lane_paged = jax.jit(
                fn, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
        frow = np.zeros((self.cache_len,), np.int32)
        if len(forced_rest):
            frow[:len(forced_rest)] = forced_rest
        (st["cur"], st["active"], st["eos"], st["budget"], st["forced"],
         st["flen"], st["fptr"]) = self._call(
            self._jit_admit_lane_paged, st["cur"], st["active"], st["eos"],
            st["budget"], st["forced"], st["flen"], st["fptr"], sl, tok,
            eos_id, bud, jnp.asarray(frow), flen)

    def park_lane(self, st, sl: int) -> None:
        """Deactivate a lane on device (preemption): masked writes go to
        the trash page from the next step on."""
        if self._jit_park is None:

            def fn(cur, active, sl):
                return cur.at[sl].set(PAD_TOKEN), active.at[sl].set(False)

            self._jit_park = jax.jit(fn, donate_argnums=(0, 1))
        st["cur"], st["active"] = self._call(self._jit_park, st["cur"],
                                             st["active"], sl)

    # -- stage-pipelined decode (mode="serve_pipeline") ------------------------

    def _pipeline_decode_fn(self, n: int):
        """Fused n-step decode streamed through the `stage` mesh axis.

        Stage s holds its contiguous slice of the scan-stacked layer
        periods (plan specs) and each decode step runs the GPipe schedule
        from core/pipeline over *lane microbatches*: at tick t, stage s
        applies its layers to microbatch (t - s) and ppermutes the hidden
        state forward — the paper's gateway-to-gateway encoder stream with
        decode micro-steps as the traffic.  The final hidden state is
        psum-shared so argmax/EOS/budget bookkeeping (the exact
        `greedy_token_update` used by `Model.decode_steps`) runs
        replicated, making the pipelined stream bit-identical to the
        single-device fused loop.
        """
        from repro.core.pipeline import (
            gpipe_forward_perm, pipeline_steps, shard_map_compat,
        )
        from repro.models.layers import lm_head, norm
        from repro.models.transformer import block_apply

        model, plan, cfg = self.model, self.plan, self.model.cfg
        mesh, axis = plan.mesh, plan.axes.stage
        n_stages = mesh.shape[axis]
        n_rep, tail, kinds = layer_plan(cfg)
        if tail or n_rep % n_stages:
            raise ValueError(
                f"serve_pipeline needs the scan-stacked periods to divide "
                f"the stage axis: n_rep={n_rep}, tail={tail}, "
                f"stages={n_stages}")
        b = self.max_batch
        n_micro = n_stages if b % n_stages == 0 else 1
        mb = b // n_micro
        steps = pipeline_steps(n_micro, n_stages)
        fwd = gpipe_forward_perm(n_stages)
        np_ = len(kinds)

        def body(scan_p, rest_p, scan_c, pos0, token, active, eos, budget):
            sidx = jax.lax.axis_index(axis)

            def decode_one(carry, _):
                cur, act, rem, pos, sc = carry
                x = model.embed_inputs(rest_p, tokens=cur[:, None])
                positions = pos[:, None]
                xm = x.reshape(n_micro, mb, 1, x.shape[-1])
                buf = jnp.zeros_like(xm[0])
                out = jnp.zeros_like(xm)

                def tick(t, c2):
                    buf, out, sc = c2
                    m = t - sidx  # microbatch this stage works on
                    stage_on = (m >= 0) & (m < n_micro)
                    row0 = jnp.clip(m, 0, n_micro - 1) * mb
                    x_in = jnp.where(sidx == 0,
                                     xm[jnp.minimum(t, n_micro - 1)], buf)
                    pos_sl = jax.lax.dynamic_slice_in_dim(
                        positions, row0, mb, 0)
                    act_sl = jax.lax.dynamic_slice_in_dim(act, row0, mb, 0)

                    def period_body(h, xs):
                        pp, pc = xs
                        pc_sl = jax.tree.map(
                            lambda a: jax.lax.dynamic_slice_in_dim(
                                a, row0, mb, 0), pc)
                        new_sl = {}
                        for i in range(np_):
                            h, ns, _ = block_apply(cfg, i, pp[f"b{i}"], h,
                                                   pos_sl, None,
                                                   pc_sl[f"b{i}"])
                            new_sl[f"b{i}"] = ns

                        def upd(full, nsl):
                            # commit the microbatch rows only for active
                            # lanes on an active stage — the pipelined
                            # form of decode_step's cache_map where-mask
                            old = jax.lax.dynamic_slice_in_dim(
                                full, row0, mb, 0)
                            keep = stage_on & act_sl.reshape(
                                (mb,) + (1,) * (nsl.ndim - 1))
                            return jax.lax.dynamic_update_slice_in_dim(
                                full, jnp.where(keep, nsl.astype(full.dtype),
                                                old), row0, 0)

                        return h, jax.tree.map(upd, pc, new_sl)

                    h, sc = jax.lax.scan(period_body, x_in, (scan_p, sc))
                    y = jnp.where(stage_on, h, buf)
                    oslot = t - (n_stages - 1)
                    write = (sidx == n_stages - 1) & (oslot >= 0)
                    out = jax.lax.cond(
                        write,
                        lambda o: jax.lax.dynamic_update_index_in_dim(
                            o, y, jnp.maximum(oslot, 0), 0),
                        lambda o: o, out)
                    buf = jax.lax.ppermute(y, axis, fwd)
                    return (buf, out, sc)

                _, out, sc = jax.lax.fori_loop(0, steps, tick,
                                               (buf, out, sc))
                # results live on the last stage; share them so the token
                # feedback loop runs replicated (0 + x is exact in bf16)
                out = jax.lax.psum(
                    jnp.where(sidx == n_stages - 1, out,
                              jnp.zeros_like(out)), axis)
                h = norm(out.reshape(b, 1, -1), rest_p["final_norm"], cfg)
                logits = lm_head(h, rest_p["embed"])[:, 0]
                emit, cur, still, rem = greedy_token_update(
                    logits, cur, act, rem, eos, PAD_TOKEN)
                pos = jnp.where(act, pos + 1, pos)
                return (cur, still, rem, pos, sc), emit

            (cur, act, rem, pos, sc), toks = jax.lax.scan(
                decode_one,
                (token.astype(jnp.int32), active, budget, pos0, scan_c),
                None, length=n)
            return toks, cur, act, rem, pos, sc

        def fn(params, caches, token, active, eos, budget):
            rest_p = {k: v for k, v in params.items() if k != "scan"}
            toks, cur, act, rem, pos, sc = shard_map_compat(
                body, mesh,
                in_specs=(P(axis), P(), P(axis), P(), P(), P(), P(), P()),
                out_specs=(P(), P(), P(), P(), P(), P(axis)),
            )(params["scan"], rest_p, caches["scan"], caches["pos"],
              token, active, eos, budget)
            return toks, cur, act, rem, {"scan": sc, "tail": {}, "pos": pos}

        kw = {}
        if self._param_shardings is not None:
            kw["in_shardings"] = ((self._param_shardings,
                                   self._cache_shardings)
                                  + (self._rep,) * 4)
            kw["out_shardings"] = ((self._rep,) * 4
                                   + (self._cache_shardings,))
        return jax.jit(fn, donate_argnums=(1,), **kw)

    # -- request-skewed pipelined decode (mode="serve_pipeline", exact=False) --

    def _pipeline_skew_decode_fn(self, n: int):
        """Fused n-step *paged* decode on the request-skewed pipeline.

        The exact pipeline drains every decode step — each token costs
        ``n_micro + n_stages - 1`` ticks and the drain idles
        (stages-1)/stages of the mesh.  The throughput schedule
        (plan.exact=False) skews stages across *request lane groups*
        instead: the batch splits into n_stages contiguous groups, and at
        tick t stage s runs group (t-s) mod G at decode step (t-s) // G —
        while stage s holds group g's step k, stage s-1 is already on
        group g+1, so the only bubbles left are the S-1 fill/drain ticks
        of the whole horizon: n*S + S - 1 ticks for n steps against
        n*(2S - 1) exact (docs/perf.md has the accounting).

        Every stage keeps its *own* position counters (it ingests group
        g's step-k token S-1 ticks after stage 0 did) and the last stage
        commits a group's step — the verbatim `decode_steps` forced-queue
        state machine on that group's rows — into the replicated lane
        state via a psum-delta every stage folds in.  Group g's step-k
        commit lands at tick g + k*G + S - 1 and the earliest step-(k+1)
        read of those rows is at tick g + (k+1)*G (G = S), so each stage
        observes exactly the state the sequential fused loop would have:
        the schedule changes *when* lanes decode, never *what* they
        decode, and streams differ from exact plans only through float
        reduction order elsewhere in the plan.

        The paged arenas ride the stage axis for free: `_stage_spec`
        shards every scan-stacked leaf's leading period dim, which for an
        arena leaf (n_rep, P, ps, KVH, hd) leaves stage s holding only its
        own layers' pages — pipeline depth multiplies usable KV HBM
        (kv_manager.kv_page_bytes(shards=)); the page table and counters
        stay replicated routing metadata.
        """
        from repro.core.pipeline import gpipe_forward_perm, shard_map_compat
        from repro.models.layers import lm_head, norm
        from repro.models.transformer import block_apply

        model, plan, cfg = self.model, self.plan, self.model.cfg
        mesh, axis = plan.mesh, plan.axes.stage
        n_stages = mesh.shape[axis]
        n_rep, tail, kinds = layer_plan(cfg)
        if tail or n_rep % n_stages:
            raise ValueError(
                f"serve_pipeline needs the scan-stacked periods to divide "
                f"the stage axis: n_rep={n_rep}, tail={tail}, "
                f"stages={n_stages}")
        b = self.max_batch
        if b % n_stages:
            raise ValueError(
                f"request-skewed serve_pipeline needs max_batch divisible "
                f"by the stage count: batch={b}, stages={n_stages}")
        n_groups = n_stages
        mb = b // n_groups
        total = n * n_groups + n_stages - 1
        fwd = gpipe_forward_perm(n_stages)
        np_ = len(kinds)
        fcap = self.cache_len

        def body(scan_p, rest_p, scan_c, pt, pos0, token, active, eos,
                 budget, forced, flen, fptr):
            sidx = jax.lax.axis_index(axis)
            buf0 = jnp.zeros_like(
                model.embed_inputs(rest_p, tokens=token[:mb][:, None]))

            def tick(t, c2):
                # lane state rides as one (4, b) int32 array — rows are
                # (cur token, active, budget, forced ptr) — so the commit
                # below is a single psum, not four (collective dispatch on
                # the host mesh is the skew schedule's marginal cost)
                buf, out, sc, pos_s, state = c2
                m = t - sidx  # global micro-step this stage works on
                stage_on = (m >= 0) & (m < n * n_groups)
                mc = jnp.clip(m, 0, n * n_groups - 1)
                g = mc % n_groups  # lane group
                k_step = mc // n_groups  # its decode step
                row0 = g * mb

                st_sl = jax.lax.dynamic_slice(state, (0, row0), (4, mb))
                cur_sl, rem_sl, fp_sl = st_sl[0], st_sl[2], st_sl[3]
                act_sl = st_sl[1].astype(bool)
                pos_sl = jax.lax.dynamic_slice_in_dim(pos_s, row0, mb, 0)
                pt_sl = jax.lax.dynamic_slice_in_dim(pt, row0, mb, 0)
                x0 = model.embed_inputs(rest_p, tokens=cur_sl[:, None])
                x_in = jnp.where(sidx == 0, x0, buf)
                # arena writes are active-gated inside attention (inactive
                # or off-schedule rows land on the trash page), so the
                # stage mask composes with the lane mask directly
                wr = act_sl & stage_on

                def period_body(h, xs):
                    pp, pc = xs
                    new_pc = {}
                    for i in range(np_):
                        h, ns, _ = block_apply(
                            cfg, i, pp[f"b{i}"], h, pos_sl[:, None], None,
                            pc[f"b{i}"], page_table=pt_sl, active=wr)
                        new_pc[f"b{i}"] = ns
                    return h, new_pc

                h, sc = jax.lax.scan(period_body, x_in, (scan_p, sc))
                y = jnp.where(stage_on, h, buf)

                # last stage: finish the group's step — logits + the
                # decode_steps forced-queue state machine on its rows
                do = stage_on & (sidx == n_stages - 1)
                hn = norm(y, rest_p["final_norm"], cfg)
                logits = lm_head(hn, rest_p["embed"])[:, 0]
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                fl_sl = jax.lax.dynamic_slice_in_dim(flen, row0, mb, 0)
                eos_sl = jax.lax.dynamic_slice_in_dim(eos, row0, mb, 0)
                fr_sl = jax.lax.dynamic_slice_in_dim(forced, row0, mb, 0)
                pending = fp_sl < fl_sl
                emitting = act_sl & ~pending
                emit = jnp.where(emitting, nxt, -1)
                rem_new = jnp.where(emitting, rem_sl - 1, rem_sl)
                still = act_sl & (pending
                                  | ((nxt != eos_sl) & (rem_new > 0)))
                feed = jnp.where(
                    pending,
                    fr_sl[jnp.arange(mb), jnp.minimum(fp_sl, fcap - 1)],
                    nxt)
                cur_new = jnp.where(still, feed, PAD_TOKEN).astype(jnp.int32)
                fp_new = jnp.where(act_sl & pending, fp_sl + 1, fp_sl)
                out = jnp.where(
                    do,
                    jax.lax.dynamic_update_slice(out, emit[None, :],
                                                 (k_step, row0)),
                    out)

                # exactly one stage has `do` per tick; a single psum-delta
                # over the packed state folds its row update into every
                # stage's replicated copy (int32 throughout, so exact)
                new_sl = jnp.stack([cur_new, still.astype(jnp.int32),
                                    rem_new, fp_new])
                upd = jax.lax.dynamic_update_slice(state, new_sl, (0, row0))
                upd = jnp.where(do, upd, state)
                state = state + jax.lax.psum(upd - state, axis)
                # this stage just ingested one token for its group's
                # active lanes: advance its own counters
                pos_s = jax.lax.dynamic_update_slice_in_dim(
                    pos_s, jnp.where(wr, pos_sl + 1, pos_sl), row0, 0)
                buf = jax.lax.ppermute(y, axis, fwd)
                return (buf, out, sc, pos_s, state)

            state0 = jnp.stack([token.astype(jnp.int32),
                                active.astype(jnp.int32),
                                budget.astype(jnp.int32),
                                fptr.astype(jnp.int32)])
            carry = (buf0, jnp.zeros((n, b), jnp.int32), scan_c, pos0,
                     state0)
            (_, out, sc, pos_s, state) = jax.lax.fori_loop(
                0, total, tick, carry)
            cur, act = state[0], state[1].astype(bool)
            rem, fp = state[2], state[3]
            # out lives on the last stage, counters agree on every stage
            # (same (group, step) sequence, same committed lane masks) —
            # share both so the outputs are replicated
            toks = jax.lax.psum(
                jnp.where(sidx == n_stages - 1, out, jnp.zeros_like(out)),
                axis)
            pos = jax.lax.psum(
                jnp.where(sidx == 0, pos_s, jnp.zeros_like(pos_s)), axis)
            return toks, cur, act, rem, fp, pos, sc

        def fn(params, caches, token, active, eos, budget, forced, flen,
               fptr):
            rest_p = {k: v for k, v in params.items() if k != "scan"}
            toks, cur, act, rem, fp, pos, sc = shard_map_compat(
                body, mesh,
                in_specs=(P(axis), P(), P(axis), P(), P(), P(), P(), P(),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P(), P(), P(), P(), P(axis)),
            )(params["scan"], rest_p, caches["scan"], caches["pt"],
              caches["pos"], token, active, eos, budget, forced, flen,
              fptr)
            return toks, cur, act, rem, fp, {"scan": sc, "tail": {},
                                             "pos": pos,
                                             "pt": caches["pt"]}

        kw = {}
        if self._param_shardings is not None:
            kw["in_shardings"] = ((self._param_shardings,
                                   self._cache_shardings)
                                  + (self._rep,) * 7)
            kw["out_shardings"] = ((self._rep,) * 5
                                   + (self._cache_shardings,))
        return jax.jit(fn, donate_argnums=(1,), **kw)
