"""Host-side serving policy (no jax): admission, preemption, the horizon
ladder, and token-stream reconciliation.

Everything here is pure scheduling state — which request enters which
lane, how many fused decode steps the next dispatch should run, how a
fetched token block maps back onto request streams, who gets preempted
under pool pressure.  Device work lives in serving/executor.py; page
accounting lives in serving/kv_manager.py; serving/engine.py composes the
three.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.packing import AdmissionPolicy
from repro.runtime.stragglers import AdmissionDeadline


@dataclass(eq=False)  # identity equality: rid is caller-chosen, prompt is a
class Request:        # numpy array (== would be ambiguous), requests mutate
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    t_arrival: float = 0.0  # seconds after engine start (Poisson streams)
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False
    n_preempts: int = 0  # preemption-cascade damping (Scheduler.victim)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    def append_token(self, tok: int, now: float) -> None:
        assert not self.done, \
            f"request {self.rid}: token appended after done"
        if not self.tokens_out:
            self.t_first_token = now
        self.tokens_out.append(int(tok))
        if tok == self.eos_id or len(self.tokens_out) >= self.max_new_tokens:
            self.done = True
            self.t_done = now

    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens_out)

    def effective_prompt(self) -> np.ndarray:
        """Prompt + tokens already generated: greedy decode is
        deterministic, so a preempted request re-enters as if its output
        so far had been part of the prompt and continues its stream."""
        if not self.tokens_out:
            return self.prompt
        return np.concatenate(
            [np.asarray(self.prompt, np.int32),
             np.asarray(self.tokens_out, np.int32)])


class Scheduler:
    """Admission ordering + decode-horizon policy for one engine.

    Owns the waiting queue and the host mirror of per-lane forced-token
    (prefix-hit suffix ingest) counts; never touches device state.
    """

    def __init__(self, buckets: Sequence[int], deadline_s: float,
                 decode_horizon: int, max_batch: int,
                 preempt_budget: int = 2):
        assert decode_horizon >= 1
        self.policy = AdmissionPolicy(
            buckets=tuple(sorted(buckets)), lane=8,
            deadline=AdmissionDeadline(deadline_s))
        self.decode_horizon = decode_horizon
        # powers of two bound the number of compiled horizon programs;
        # decode_horizon=1 is the one-dispatch-per-token baseline
        self.horizons = [h for h in (1, 2, 4, 8, 16, 32, 64, 128)
                         if h <= decode_horizon] or [1]
        self.queue: List[Request] = []
        self.lane_forced = [0] * max_batch  # host mirror of suffix ingest
        # preemption-cascade damping: a request preempted this many times
        # is excluded from victim() and jumps the admission order instead,
        # so a hot shared prefix can't starve one lane through the
        # evict/preempt loop indefinitely
        self.preempt_budget = preempt_budget
        # speculation-depth ladder (speculative decoding; set_spec): each
        # lane carries an acceptance EWMA and its own depth; a dispatch
        # speculates at the shallowest occupied lane's depth so no lane
        # pays for draft tokens its stream keeps rejecting
        self.spec_ladder: List[int] = []
        self.lane_spec_k = [0] * max_batch
        self.lane_accept = [1.0] * max_batch
        # lane groups (request-skewed pipeline; set_lane_groups): lanes
        # partition into contiguous groups, one per pipeline stage offset
        self.max_batch = max_batch
        self.n_lane_groups = 1
        # disaggregated prefill/decode (set_disagg): a classifier splits
        # the waiting queue into a prefill queue (cold prompts — they owe
        # the prefill pool a bucketed dispatch) and a decode-ingest queue
        # (radix prefix hits — they skip prefill AND the page transfer),
        # each with its own occupancy signal for the fleet router
        self._hit_len = None  # classify(req) -> cached-prefix positions
        self.prefill_chunk = 1

    # -- disaggregated queues (prefill pool vs decode ingest) ----------------

    def set_disagg(self, hit_len, prefill_chunk: int = 1) -> None:
        """Enable the split admission queues.  `hit_len(req)` returns the
        request's advisory cached-prefix length in positions (0 = cold —
        the request owes the prefill pool a bucketed dispatch; > 0 = the
        decode pool can ingest it directly).  `prefill_chunk` caps
        non-overdue cold admissions per cycle so a long-prompt burst
        cannot monopolize consecutive admission windows — the TTFT knob
        the disaggregation buys (docs/serving.md §disaggregated
        serving)."""
        assert prefill_chunk >= 1
        self._hit_len = hit_len
        self.prefill_chunk = prefill_chunk

    # -- lane groups (request-skewed serve_pipeline) -------------------------

    def set_lane_groups(self, n_groups: int) -> None:
        """Partition the lanes into `n_groups` contiguous groups — the
        request-skewed pipeline's unit of schedule offset (stage s runs
        group g while stage s-1 runs group g+1).  Groups are fixed slabs
        of the batch (lane i belongs to group i // (max_batch/n_groups)):
        a lane never changes group, so admission/preemption churn can't
        interleave two groups' decode positions mid-flight."""
        assert n_groups >= 1 and self.max_batch % n_groups == 0, \
            (self.max_batch, n_groups)
        self.n_lane_groups = n_groups

    def lane_group(self, slot: int) -> int:
        return slot // (self.max_batch // self.n_lane_groups)

    def order_free(self, free: List[int],
                   slots: Sequence[Optional[Request]]) -> List[int]:
        """Admission order over free slots: fill the emptiest lane group
        first (ties: lowest group, then lowest slot).  The skewed
        schedule runs every group each tick, so a group left empty while
        another saturates is pure bubble — balancing admissions across
        groups is the host-side half of filling the pipeline, and because
        every group gains occupants before any group gains a second one,
        no group (and no lane) can starve behind a hot neighbour."""
        if self.n_lane_groups <= 1:
            return free
        occ = [0] * self.n_lane_groups
        for i, r in enumerate(slots):
            if r is not None:
                occ[self.lane_group(i)] += 1
        # rank = the group's occupancy *as of this slot's admission* (one
        # cycle admits down the list in order), so a burst round-robins
        # the groups instead of packing the first one solid
        rank, seen = {}, [0] * self.n_lane_groups
        for s in sorted(free):
            g = self.lane_group(s)
            rank[s] = occ[g] + seen[g]
            seen[g] += 1
        return sorted(free, key=lambda s: (rank[s], self.lane_group(s), s))

    # -- queue ---------------------------------------------------------------

    def enqueue(self, req: Request) -> None:
        req.t_enqueue = time.perf_counter()
        self.queue.append(req)

    def queue_depth(self, pool: Optional[str] = None) -> int:
        """Requests waiting for admission (the fleet router's shedding
        signal: serving/router.py sheds when every replica's depth
        exceeds its configured budget).

        pool=None counts the whole queue (the router's historical
        signal); pool="prefill" counts requests owing a prefill-pool
        dispatch, pool="decode" the decode-ingest (prefix-hit) queue —
        with no classifier set, every admission pays prefill, so
        "prefill" is the whole queue and "decode" empty."""
        if pool is None:
            return len(self.queue)
        assert pool in ("prefill", "decode"), pool
        if self._hit_len is None:
            return len(self.queue) if pool == "prefill" else 0
        hits = sum(self._hit_len(r) > 0 for r in self.queue)
        return hits if pool == "decode" else len(self.queue) - hits

    def projected_occupancy(self, pool: Optional[str] = None) -> int:
        """Projected queued work in token-steps: per waiting request, the
        bucketed prompt cost (prefill rides a bucket-padded dispatch) plus
        the decode budget still owed.  The fleet router's least-loaded
        placement ranks replicas by this figure — it is the queue-side
        analogue of `order_free`'s per-group occupancy ranking, exported
        because between `run()` drains the queue is the whole backlog.

        pool=None is the combined figure (back-compatible).  Under
        disaggregation the two pools carry different work for the same
        request: pool="prefill" sums the bucketed prompt cost of COLD
        queued requests only (what the prefill pool owes — the signal a
        router uses to route around a saturated prefill pool);
        pool="decode" sums every request's decode budget plus, for
        prefix hits, the un-hit suffix it re-ingests through the forced
        queue (hits never touch the prefill pool)."""
        if pool is None:
            return sum(self.policy.bucket_of(len(r.prompt)) + r.remaining()
                       for r in self.queue)
        assert pool in ("prefill", "decode"), pool
        hit = self._hit_len if self._hit_len is not None else (lambda r: 0)
        if pool == "prefill":
            return sum(self.policy.bucket_of(len(r.prompt))
                       for r in self.queue if hit(r) <= 0)
        return sum(r.remaining()
                   + (max(0, len(r.prompt) - h) if (h := hit(r)) > 0 else 0)
                   for r in self.queue)

    def take_queue(self) -> List[Request]:
        pending, self.queue = self.queue, []
        return pending

    def select(self, arrived: Sequence[Request], n_free: int, warm,
               now: float) -> List[Request]:
        """Requests to admit, in order (deadline-overdue FIFO first, then
        warm buckets — core/packing.AdmissionPolicy)."""
        pick = self.policy.select(arrived, n_free, warm=warm, now=now)
        return [arrived[p] for p in pick]

    def admission_cycle(self, pending, free: List[int], now: float, warm,
                        admit):
        """One admission pass: call ``admit(req, slot)`` for each selected
        arrival while free slots last.  Returns (admitted [(req, slot)],
        starved) — `starved` is the head-of-line request the backing store
        couldn't cover (admit returned False; nothing was mutated for it),
        the signal for preempt-to-free."""
        arrived = [r for r in pending if r.t_arrival <= now]
        admitted, starved = [], None
        if free and arrived:
            # preemption-cascade damping: victims already preempted to
            # their budget are admitted first (FIFO among themselves),
            # ahead of the policy's ordering — they have paid for their
            # pages enough times
            hot = [r for r in arrived if r.n_preempts >= self.preempt_budget]
            rest = [r for r in arrived if r.n_preempts < self.preempt_budget]
            if self._hit_len is not None:
                order = hot + self._disagg_order(rest, now)
            else:
                order = hot + (self.select(rest, len(free) - len(hot),
                                           warm, now)
                               if rest and len(free) > len(hot) else [])
            for r in order:
                if not free:
                    break
                if not admit(r, free[0]):
                    starved = r
                    break
                admitted.append((r, free.pop(0)))
        return admitted, starved

    def _disagg_order(self, rest, now: float):
        """Admission order under split queues.  Decode-ingest requests
        (advisory prefix hits) admit first, FIFO, without limit — they
        cost the decode pool a pt/reset update and zero prefill-pool or
        transfer work.  Cold requests go through the prefill pool:
        deadline-overdue ones keep their FIFO guarantee, then at most
        `prefill_chunk` more per cycle ordered shortest-bucket-first —
        SJF bounds how long a long-prompt burst can stall the short
        steady traffic queued behind it, and because admission order
        changes only WHEN a request runs (greedy lanes decode
        independently), the streams stay bit-identical to colocated
        FIFO serving."""
        ingest = [r for r in rest if self._hit_len(r) > 0]
        cold = [r for r in rest if self._hit_len(r) <= 0]
        dl = self.policy.deadline
        overdue = [r for r in cold
                   if dl is not None and dl.overdue(now - r.t_arrival)]
        fresh = sorted((r for r in cold if r not in overdue),
                       key=lambda r: (self.policy.bucket_of(len(r.prompt)),
                                      r.t_enqueue))
        return ingest + overdue + fresh[:self.prefill_chunk]

    @staticmethod
    def idle_wait(pending, starved, now: float) -> None:
        """Nothing resident: sleep to the next arrival, or a beat while a
        pool-starved admission waits for eviction to free pages."""
        if starved is not None:
            time.sleep(0.0005)
        elif pending:
            wait = min(r.t_arrival for r in pending) - now
            if wait > 0:
                time.sleep(min(wait, 0.005))

    def should_preempt(self, starved, now: float) -> bool:
        """Deadline pressure on a pool-starved admission triggers
        preempt-to-free."""
        return (starved is not None and self.policy.deadline is not None
                and self.policy.deadline.overdue(now - starved.t_arrival))

    # -- horizon -------------------------------------------------------------

    def pick_horizon(self, waiting: bool, remaining: List[int]) -> int:
        """Adaptive decode horizon from admission pressure.

        With `waiting` requests, aim for the next *predicted* completion
        (min remaining budget) so a slot frees — and is refilled — at the
        earliest useful horizon boundary, floored at 4 steps so dispatch
        overhead stays amortized (a completion can overshoot by at most 3
        masked slot-steps); with a drained queue run up to the longest
        remaining budget.  EOS can still end a lane mid-horizon; those
        lanes decode masked until the boundary (wasted slot-steps, never
        wrong tokens)."""
        if waiting:
            target = max(min(remaining), min(4, self.decode_horizon))
        else:
            target = max(remaining)
        n = 1
        for h in self.horizons:
            if h <= max(1, target):
                n = h
        return n

    def lane_remaining(self, slots: Sequence[Optional[Request]]) -> List[int]:
        """Per-occupied-lane work left: pending forced ingest + budget."""
        return [self.lane_forced[i] + r.remaining()
                for i, r in enumerate(slots) if r is not None]

    def consume_forced(self, slots: Sequence[Optional[Request]],
                       n: int) -> None:
        for i in range(len(slots)):
            if slots[i] is not None:
                self.lane_forced[i] = max(0, self.lane_forced[i] - n)

    # -- reconciliation ------------------------------------------------------

    @staticmethod
    def append_block(block: np.ndarray, requests, now: float) -> None:
        """Reconcile one fetched (n, B) token block into request streams.

        -1 marks a step at which the lane emitted nothing: a free slot, a
        lane that early-exited on device after EOS/budget (-1 *suffix*), or
        a prefix-hit lane still ingesting its prompt suffix through the
        forced-token queue (-1 *prefix*) — so -1 entries are skipped, not
        treated as end-of-block.  Device-side masking mirrors
        `Request.append_token`'s done rule, so the host appends every
        non-negative token until its own done flag flips; nothing real can
        follow a lane's device-side exit."""
        for i, r in enumerate(requests):
            if r is None or r.done:
                continue
            for tok in block[:, i]:
                if tok < 0:
                    continue
                r.append_token(int(tok), now)
                if r.done:
                    break

    def reconcile(self, block: np.ndarray, slots, done: List[Request],
                  n: int, stats: dict, now: float, paged: bool,
                  on_release=None) -> None:
        """Post-dispatch bookkeeping: account the fused dispatch, mirror
        suffix-ingest consumption, append streams, sweep completed lanes
        (calling ``on_release(slot)`` for paged page returns)."""
        stats["decode_dispatches"] += 1
        stats["decode_steps"] += n
        stats["device_syncs"] += 1
        stats["active_lane_steps"] += sum(r is not None for r in slots) * n
        if paged:
            self.consume_forced(slots, n)
        self.append_block(block, slots, now)
        for i, r in enumerate(slots):
            if r is not None and r.done:
                done.append(r)
                slots[i] = None  # device lane already inactive
                if on_release is not None:
                    on_release(i)
                stats["completed"] += 1

    # -- preemption ----------------------------------------------------------

    def victim(self, slots: Sequence[Optional[Request]]) -> Optional[int]:
        """The occupied lane with the most work left (it holds the most
        still-unearned pages); None when nothing runs.  Lanes whose
        occupant has exhausted its preemption budget are exempt — without
        the damping, a hot shared prefix keeps re-admitting over the same
        victim and one request ping-pongs between lane and queue forever
        (tests/test_serving.py::test_preemption_budget_stops_cascade)."""
        occ = [(i, r) for i, r in enumerate(slots)
               if r is not None and r.n_preempts < self.preempt_budget]
        if not occ:
            return None
        return max(occ, key=lambda ir: ir[1].remaining())[0]

    # -- speculation depth ---------------------------------------------------

    def set_spec(self, spec_k: int) -> None:
        """Enable the speculation-depth ladder up to `spec_k` drafted
        tokens per dispatch (powers of two, like the horizon ladder, to
        bound compiled spec programs)."""
        assert spec_k >= 1
        self.spec_ladder = [h for h in (1, 2, 4, 8) if h <= spec_k] or [1]
        top = self.spec_ladder[-1]
        self.lane_spec_k = [top] * len(self.lane_spec_k)
        self.lane_accept = [1.0] * len(self.lane_accept)

    def reset_lane_spec(self, slot: int) -> None:
        """New occupant: start at full depth with a clean acceptance EWMA
        (greedy acceptance is a property of the stream, not the lane)."""
        if self.spec_ladder:
            self.lane_spec_k[slot] = self.spec_ladder[-1]
            self.lane_accept[slot] = 1.0

    def observe_acceptance(self, slot: int, accepted: int, k: int) -> None:
        """Fold one dispatch's acceptance (accepted drafted tokens out of
        k proposed) into the lane's EWMA and walk its depth along the
        ladder: persistent rejection shrinks k toward 1 (each rejected
        draft costs a wasted draft forward + verify row), sustained
        acceptance grows it back."""
        if not self.spec_ladder:
            return
        acc = accepted / max(k, 1)
        ew = self.lane_accept[slot] = (0.5 * self.lane_accept[slot]
                                       + 0.5 * acc)
        cur = self.lane_spec_k[slot]
        i = self.spec_ladder.index(cur)
        if ew < 0.4 and i > 0:
            self.lane_spec_k[slot] = self.spec_ladder[i - 1]
        elif ew > 0.8 and i < len(self.spec_ladder) - 1:
            self.lane_spec_k[slot] = self.spec_ladder[i + 1]

    def spec_depth(self, slots: Sequence[Optional[Request]],
                   starved: bool) -> int:
        """Drafted tokens for the next dispatch: the shallowest occupied
        lane's ladder depth, or 0 (speculation off, plain fused decode)
        under admission pressure — a pool-starved arrival means every
        speculative margin page is a page eviction could free, and the
        overshoot past completion boundaries delays the slot hand-off."""
        if not self.spec_ladder or starved:
            return 0
        ks = [self.lane_spec_k[i] for i, r in enumerate(slots)
              if r is not None]
        return min(ks) if ks else 0
