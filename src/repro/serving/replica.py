"""One serving replica of the fleet: a ContinuousBatchingEngine plus the
identity and load/hit-rate surface the FleetRouter routes against.

A replica is a whole single-process serving stack — its own serve plan,
paged arena, and radix prefix tree — placed on a disjoint device group of
the host mesh (multi-process `jax.distributed` fleets are out of scope;
see docs/fleet.md).  The router never reaches inside the engine: the
three methods it needs (`queue_depth`, `projected_occupancy`, `stats`)
are the replica's published surface, so a future cross-process replica
only has to speak this interface over a wire.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.scheduler import Request


def replica_device_groups(n: int, width: int = 1,
                          devices: Optional[Sequence] = None) -> List[list]:
    """Partition the host's devices into `n` disjoint groups of `width`
    (replica i gets devices [i*width, (i+1)*width)).  Raises when the
    host cannot cover the fleet — the caller chose the replica count, so
    silently overlapping groups would just serialize on the hardware."""
    import jax
    devices = list(devices) if devices is not None else jax.devices()
    need = n * width
    if need > len(devices):
        raise ValueError(
            f"fleet: {n} replicas x {width} devices = {need} devices, "
            f"host has {len(devices)}")
    return [devices[i * width:(i + 1) * width] for i in range(n)]


def make_group_mesh(devs: Sequence, shape: Sequence[int],
                    axes: Sequence[str]):
    """A mesh over one replica's device group (jax.make_mesh always spans
    every visible device, so fleet placement builds Mesh directly)."""
    from jax.sharding import Mesh
    arr = np.empty(len(devs), dtype=object)
    for i, d in enumerate(devs):
        arr[i] = d
    return Mesh(arr.reshape(tuple(shape)), tuple(axes))


class Replica:
    """Engine + identity.  Owns nothing the engine doesn't already own —
    the value added is the routing surface and per-replica stat deltas."""

    def __init__(self, idx: int, engine: ContinuousBatchingEngine):
        self.idx = idx
        self.engine = engine
        self.routed = 0            # requests this replica was handed
        self.wall_s = 0.0          # cumulative run() wall time
        self._stat0 = dict(engine.stats)  # baseline for delta stats

    # -- routing surface -----------------------------------------------------

    def submit(self, req: Request) -> None:
        self.routed += 1
        self.engine.submit(req)

    def queue_depth(self) -> int:
        return self.engine.sched.queue_depth()

    def projected_occupancy(self) -> int:
        return self.engine.sched.projected_occupancy()

    def run(self) -> List[Request]:
        import time
        t0 = time.perf_counter()
        done = self.engine.run()
        self.wall_s += time.perf_counter() - t0
        return done

    # -- per-replica stats (prefix hit rates for the router) -----------------

    def stats(self) -> Dict[str, float]:
        """Engine stat deltas since this replica joined the fleet, plus
        the derived prefix hit rate the router's affinity accounting
        reads (hits / admissions; 0.0 before any admission)."""
        cur = self.engine.stats
        out: Dict[str, float] = {"replica": self.idx, "routed": self.routed,
                                 "wall_s": round(self.wall_s, 6)}
        for k in ("admitted", "completed", "prefills",
                  "prefix_hits", "prefix_hit_tokens", "preemptions"):
            if k in cur:
                out[k] = cur[k] - self._stat0.get(k, 0)
        admitted = out.get("admitted", 0)
        out["prefix_hit_rate"] = (out.get("prefix_hits", 0) / admitted
                                  if admitted else 0.0)
        return out
