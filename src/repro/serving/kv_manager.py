"""KV memory management for the serving engine (host-side, no jax).

Owns everything about *where cache bytes live*: the paged arena's
free-list allocator (`core/packing.PagePool`), the radix prefix cache that
lets requests share prompt KV copy-free, the per-lane page lists, and the
page-table / reset rows an admission hands to the executor.  The split
mirrors the paper's memory story: on-chip URAM is the scarce resource the
Cluster Builder budgets per kernel; here KV HBM is budgeted per page, and
the KV manager is the single owner of that budget.

The executor (serving/executor.py) consumes the numpy rows built here as
jit operands; the scheduler (serving/scheduler.py) consumes the
free-page / eviction signals as admission gates.  Neither touches the
pool directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.packing import PagePool, RadixPrefixCache


def kv_page_bytes(cfg, page_size: int, kv_dtype: str,
                  shards: int = 1) -> int:
    """HBM bytes one KV arena page costs *per device* — the unit for
    equal-HBM pool sizing (docs/perf.md §int8 pages).

    bf16: 2 (k+v) * KVH * hd elements at 2 B per cache row; int8: the same
    elements at 1 B plus 2 * KVH f32 scales per row, i.e. (hd+4)/(2*hd) of
    the bf16 bytes — a fixed budget holds ~2x the pages at hd=64.

    shards > 1: the arena is stage-sharded (exact=False serve_pipeline —
    each stage holds only its own layers' slice of every page), so a page
    costs 1/shards of the full stack per device and a per-device budget
    buys shards× the pages.  The division only applies when the layer
    stack actually divides; otherwise the arena replicates and a page
    costs its full span everywhere — sizing with the divided figure there
    is exactly the over-subscription bug the per-shard residency ledger
    (`KVManager(shards=)`) guards against.
    """
    per_row = 2 * cfg.n_kv_heads * cfg.head_dim  # k+v elements
    if kv_dtype == "int8":
        row_bytes = per_row + 2 * cfg.n_kv_heads * 4  # values + f32 scales
    else:
        row_bytes = per_row * 2
    n_layers = cfg.n_layers
    if shards > 1 and n_layers % shards == 0:
        n_layers //= shards
    return n_layers * page_size * row_bytes


def num_pages_for_hbm(cfg, page_size: int, kv_dtype: str,
                      hbm_bytes: int, shards: int = 1) -> int:
    """Pool size (usable pages) a *per-device* byte budget buys at this
    dtype; with a stage-sharded arena (shards=stage depth) the same
    budget holds shards× the pages."""
    return int(hbm_bytes // kv_page_bytes(cfg, page_size, kv_dtype,
                                          shards=shards))


def spec_pool_split(cfg, draft_cfg, page_size: int, kv_dtype: str,
                    hbm_bytes: int) -> int:
    """Pages *per arena* one HBM byte budget buys when a target and a
    draft arena share it position-for-position (speculative decoding):
    every lane position costs one target row plus one draft row, so the
    two pools hold the same page count and the budget divides by the
    summed per-page cost.  docs/serving.md §speculative decoding."""
    both = (kv_page_bytes(cfg, page_size, kv_dtype)
            + kv_page_bytes(draft_cfg, page_size, kv_dtype))
    return int(hbm_bytes // both)


def paged_eligible(cfg, plan=None) -> bool:
    """Can this (config, plan) pair serve from the paged arena?  The one
    predicate the engine's ``paged="auto"`` and the serve CLI's guards
    share: all-attention, unwindowed, causal (recurrent state and ring
    buffers have no paged analogue), under no plan, a ``mode="serve"``
    plan, or a throughput (exact=False) ``serve_pipeline`` plan — the
    request-skewed schedule decodes straight from stage-local arenas,
    while the *exact* pipeline streams the dense slot path."""
    from repro.models.transformer import layer_plan  # lazy: pulls jax
    _, _, kinds = layer_plan(cfg)
    return (all(k == "attn" for k in kinds) and not cfg.local_window
            and bool(cfg.causal)
            and (plan is None or plan.mode == "serve"
                 or (plan.mode == "serve_pipeline"
                     and not getattr(plan, "exact", True))))


@dataclass
class AdmissionGrant:
    """Everything one paged admission needs: the lane's full page list,
    the covered prefix length (0 = cold), and the executor-ready rows —
    `pt_row` (the lane's page table, trash-padded) and `reset` (pages
    whose kpos must re-sentinel before use, trash-padded)."""
    pages: List[int]
    hit_pages: List[int]
    hit_len: int
    pt_row: np.ndarray
    reset: np.ndarray
    # speculative decoding only: the lane's draft-arena pages (always
    # exclusively owned — the draft arena has no radix tree, its content
    # is disposable lookahead state) and their executor-ready rows
    draft_pages: Optional[List[int]] = None
    draft_pt_row: Optional[np.ndarray] = None
    draft_reset: Optional[np.ndarray] = None


class KVManager:
    """Page-pool + radix-tree owner for one engine.

    Reference-count discipline: a page is held by the lane that owns it
    (`_lane_pages`), by the radix tree once registered, and by any lane
    that hit on it; `release()` drops the lane references and the tree
    keeps registered prefix pages alive for future hits.

    shards > 1: the arena is sharded (stage-local arenas under a
    throughput serve_pipeline plan, kv-head TP under serve), so one
    logical page is physically a slab on *every* shard.  The manager then
    keeps a per-shard residency ledger updated from the pages each
    alloc/release/eviction ACTUALLY freed (`PagePool.decref` /
    `RadixPrefixCache.evict` return counts) — not from the requested
    full-span count, which over-frees per-shard bytes whenever a decref
    lands on a still-shared page.  `assert_drained` cross-checks every
    shard's ledger against the pool, so a cross-stage page leak (one
    stage's slab freed, another's stranded) fails loudly at drain.
    """

    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 max_pages: int, draft_num_pages: int = 0,
                 shards: int = 1):
        self.pool = PagePool(num_pages, page_size)
        self.prefix_cache = RadixPrefixCache(self.pool)
        self.page_size = page_size
        self.max_pages = max_pages  # page-table row width (per-lane cap)
        self._lane_pages: List[Optional[List[int]]] = [None] * max_batch
        self.shards = max(1, int(shards))
        # per-shard resident page slabs (one logical page = one slab on
        # each shard); kept explicitly so drift from mis-accounted frees
        # is detectable rather than silently oversubscribing HBM
        self._shard_pages = np.zeros(self.shards, np.int64)
        # speculative decoding's second arena: same page granularity, no
        # radix tree (draft KV is disposable lookahead — never shared, and
        # rejection rollback is a device-side position rewind, so the page
        # accounting is purely lane-owned alloc/release).  Both pools draw
        # on one HBM budget via spec_pool_split.
        self.draft_pool: Optional[PagePool] = (
            PagePool(draft_num_pages, page_size) if draft_num_pages else None)
        self._draft_lane_pages: List[Optional[List[int]]] = \
            [None] * max_batch

    # -- per-shard residency ---------------------------------------------------

    def _resident(self, n: int) -> None:
        self._shard_pages += n

    def _freed(self, n: int) -> None:
        self._shard_pages -= n
        assert (self._shard_pages >= 0).all(), self._shard_pages

    def shard_pages_in_use(self, shard: int = 0) -> int:
        return int(self._shard_pages[shard])

    def evict_cached(self, n_pages: int) -> int:
        """Evict up to `n_pages` LRU cached prefix pages through the
        per-shard ledger (the only correct external eviction path — a
        bare `prefix_cache.evict()` would desync `_shard_pages`).
        Returns the count actually freed (shared pages stay resident)."""
        freed = self.prefix_cache.evict(n_pages)
        self._freed(freed)
        return freed

    def stage_view(self, shard: int) -> "StageArenaView":
        """Read-only accounting view of one shard's slice of the arena —
        what a pipeline stage 'owns' (its layers' slabs of every resident
        page) without handing it the allocator."""
        return StageArenaView(self, shard)

    # -- capacity ------------------------------------------------------------

    def pages_for(self, n_positions: int) -> int:
        return self.pool.pages_for(n_positions)

    @property
    def num_pages(self) -> int:
        return self.pool.num_pages

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def pages_in_use(self) -> int:
        return self.pool.pages_in_use

    # -- admission -----------------------------------------------------------

    def admit(self, prompt: np.ndarray, rem_budget: int,
              max_hit_suffix: int,
              spec_margin: int = 0) -> Optional[AdmissionGrant]:
        """Reserve pages for `prompt` + `rem_budget` decode positions.

        Radix lookup first: a hit reuses the shared prefix pages (already
        incref'd by lookup) and only the un-hit remainder is allocated; a
        hit whose suffix exceeds `max_hit_suffix` is declined (one dense
        prefill is cheaper than re-ingesting that many tokens through the
        decode loop).  Under pool pressure cached prefixes are LRU-evicted
        before giving up.  Returns None (nothing held) when the pool can't
        cover the request — the scheduler may then preempt-to-free.

        spec_margin (speculative decoding): extra positions both arenas
        must be able to scatter — a speculative block writes up to
        `spec_k` rows past the lane's committed position before acceptance
        is known, and a clipped page-table gather would otherwise alias
        the lane's last page.  When a draft pool exists the lane also
        needs the same positions in the draft arena (no radix there: the
        full span is always exclusively owned); if the draft pool can't
        cover it the target-side reservation is rolled back and the
        admission declines as a unit.
        """
        pool = self.pool
        need_positions = len(prompt) + rem_budget + spec_margin
        need_pages = pool.pages_for(need_positions)
        hit_pages, hit_len = self.prefix_cache.lookup(prompt)
        if hit_len and len(prompt) - hit_len > max_hit_suffix:
            # suffix too long: prefill is cheaper
            self._freed(len(pool.decref(hit_pages)))
            hit_pages, hit_len = [], 0
        own_need = need_pages - len(hit_pages)
        if own_need > pool.free_pages:
            # eviction frees per-shard slabs: the ledger moves by the
            # pages the tree ACTUALLY freed on every shard, not by the
            # requested full-span count (shared pages stay resident)
            self._freed(self.prefix_cache.evict(own_need - pool.free_pages))
        if own_need > pool.free_pages:
            self._freed(len(pool.decref(hit_pages)))
            return None
        draft_pages = draft_pt = draft_reset = None
        if self.draft_pool is not None:
            draft_need = self.draft_pool.pages_for(need_positions)
            if draft_need > self.draft_pool.free_pages:
                self._freed(len(pool.decref(hit_pages)))
                return None
            draft_pages = self.draft_pool.alloc(draft_need)
            draft_pt = np.zeros((self.max_pages,), np.int32)
            draft_pt[:len(draft_pages)] = draft_pages
            draft_reset = np.zeros((self.max_pages,), np.int32)
            draft_reset[:len(draft_pages)] = draft_pages
        own = pool.alloc(own_need)
        self._resident(len(own))
        pages = hit_pages + own
        pt_row = np.zeros((self.max_pages,), np.int32)
        pt_row[:len(pages)] = pages
        reset = np.zeros((self.max_pages,), np.int32)  # trash-page padded
        reset[:len(own)] = own
        return AdmissionGrant(pages=pages, hit_pages=hit_pages,
                              hit_len=hit_len, pt_row=pt_row, reset=reset,
                              draft_pages=draft_pages, draft_pt_row=draft_pt,
                              draft_reset=draft_reset)

    def peek_hit(self, prompt: np.ndarray) -> int:
        """Advisory prefix-hit length (positions) for `prompt` — no
        references taken, no LRU/stat mutation.  The disaggregated
        scheduler classifies queued requests with this (hit => the
        decode-ingest queue, no prefill-pool work); `admit` re-checks at
        admission time, so a stale answer only mis-sorts the queue."""
        return self.prefix_cache.peek(prompt)

    # -- page shipping (disaggregated prefill pool side) ----------------------

    def stage_export(self, n_pages: int) -> AdmissionGrant:
        """Reserve `n_pages` staging pages in THIS manager's arena (the
        prefill pool's) for one admission's prefill KV, to be shipped to
        a decode-pool arena and then released via `finish_export`.

        Returns an AdmissionGrant whose pt_row/reset rows drive the
        executor's `prefill_admit` scatter at staging slot 0 — the exact
        rows a colocated cold admission would build, so the staged page
        contents are bitwise what `admit_cold` writes.  Exports are
        transient (one in flight per admission), so a pool sized
        `max_pages + 1` can never decline."""
        own = self.pool.alloc(n_pages)
        self._resident(len(own))
        pt_row = np.zeros((self.max_pages,), np.int32)
        pt_row[:len(own)] = own
        reset = np.zeros((self.max_pages,), np.int32)
        reset[:len(own)] = own
        return AdmissionGrant(pages=own, hit_pages=[], hit_len=0,
                              pt_row=pt_row, reset=reset)

    def finish_export(self, pages: List[int]) -> None:
        """Release a `stage_export` reservation after its pages were
        shipped.  Ledger moves by the pages ACTUALLY freed (the same
        discipline as `release`), so a future prefill-side prefix cache
        sharing staged pages stays correctly accounted."""
        self._freed(len(self.pool.decref(pages)))

    def commit(self, slot: int, grant: AdmissionGrant) -> None:
        self._lane_pages[slot] = grant.pages
        if grant.draft_pages is not None:
            self._draft_lane_pages[slot] = grant.draft_pages

    def register_prefix(self, prompt: np.ndarray, pages: List[int]) -> int:
        """Register a cold prompt's full pages for future prefix hits —
        hit-path suffix pages are never registered (their KV fills in over
        later decode dispatches; a preemption could strand them
        half-written)."""
        return self.prefix_cache.insert(prompt, pages)

    def release(self, slot: int) -> None:
        """Return lane `slot`'s page references (tree references keep
        registered prefix pages alive for future hits)."""
        if self._lane_pages[slot] is not None:
            self._freed(len(self.pool.decref(self._lane_pages[slot])))
            self._lane_pages[slot] = None
        if self._draft_lane_pages[slot] is not None:
            # draft pages are never shared (no tree refs), so this frees
            # them unconditionally — retirement, preemption, and
            # rejection-rollback all reduce to the same lane release
            self.draft_pool.decref(self._draft_lane_pages[slot])
            self._draft_lane_pages[slot] = None

    # -- invariants ----------------------------------------------------------

    def assert_drained(self) -> None:
        """When the engine drains, the only live page references are the
        radix tree's — anything else is a leak.  With a sharded arena the
        per-shard ledgers must all agree with the pool: a shard whose
        slab count drifted means some path freed (or kept) pages on one
        stage's slice without the others — a cross-stage page leak."""
        assert all(p is None for p in self._lane_pages), self._lane_pages
        assert self.pool.pages_in_use == self.prefix_cache.cached_pages, (
            self.pool.pages_in_use, self.prefix_cache.cached_pages)
        assert (self._shard_pages == self.pool.pages_in_use).all(), (
            self._shard_pages, self.pool.pages_in_use)
        if self.draft_pool is not None:
            assert all(p is None for p in self._draft_lane_pages), \
                self._draft_lane_pages
            assert self.draft_pool.pages_in_use == 0, \
                self.draft_pool.pages_in_use


class StageArenaView:
    """One shard's (pipeline stage's) accounting window on the arena.

    Stage s physically holds its own layers' slice of every page; this
    view reports residency/capacity in that stage's terms — pages are
    global (the page table is shared routing metadata), bytes are local.
    Read-only: all allocation goes through the owning KVManager, which is
    what keeps the shards' ledgers moving in lockstep.
    """

    def __init__(self, mgr: KVManager, shard: int):
        assert 0 <= shard < mgr.shards, (shard, mgr.shards)
        self._mgr, self.shard = mgr, shard

    @property
    def pages_in_use(self) -> int:
        return self._mgr.shard_pages_in_use(self.shard)

    @property
    def free_pages(self) -> int:
        return self._mgr.pool.free_pages

    def resident_bytes(self, cfg, kv_dtype: str = "bf16") -> int:
        """This stage's HBM actually held by resident pages."""
        return self.pages_in_use * kv_page_bytes(
            cfg, self._mgr.page_size, kv_dtype, shards=self._mgr.shards)
