"""Synthetic request streams for the serving drivers and benchmarks.

One generator and one warmup/measure harness shared by launch/serve.py,
examples/serve_batched.py and benchmarks/run.py, so arrival semantics
(`t_arrival` = seconds after the engine's run() starts, exponential
inter-arrival gaps) and measurement methodology stay in one place.
"""
from __future__ import annotations

import time
from typing import List, Tuple, Union

import numpy as np

from repro.serving.engine import Request


def poisson_requests(rng: np.random.Generator, n: int, vocab_size: int,
                     len_range: Tuple[int, int] = (4, 30),
                     budgets: Union[int, Tuple[int, int]] = 8,
                     rate: float = 0.0) -> List[Request]:
    """n requests with uniform prompt lengths in ``len_range``, decode
    budgets fixed (int) or uniform in a (lo, hi) range, and Poisson
    arrivals at ``rate`` req/s (0 = everything arrives at t=0)."""
    lengths = rng.integers(len_range[0], len_range[1], n)
    if isinstance(budgets, tuple):
        buds = rng.integers(budgets[0], budgets[1], n)
    else:
        buds = np.full(n, budgets)
    gaps = (rng.exponential(1.0 / rate, n) if rate > 0
            else np.zeros(n))
    arrivals = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab_size,
                                        lengths[i]).astype(np.int32),
                    max_new_tokens=int(buds[i]),
                    t_arrival=float(arrivals[i]))
            for i in range(n)]


def shared_prefix_requests(rng: np.random.Generator, n: int, vocab_size: int,
                           prefix_len: int = 48,
                           suffix_range: Tuple[int, int] = (3, 9),
                           budgets: Union[int, Tuple[int, int]] = (16, 48),
                           rate: float = 0.0) -> List[Request]:
    """n requests sharing one ``prefix_len``-token system prompt with unique
    uniform-length tails — the chatbot/agent ingress shape the radix prefix
    cache targets: every admission after the first can reuse the prefix's
    KV pages and skip its prefill."""
    prefix = rng.integers(0, vocab_size, prefix_len).astype(np.int32)
    suffixes = rng.integers(suffix_range[0], suffix_range[1], n)
    if isinstance(budgets, tuple):
        buds = rng.integers(budgets[0], budgets[1], n)
    else:
        buds = np.full(n, budgets)
    gaps = (rng.exponential(1.0 / rate, n) if rate > 0 else np.zeros(n))
    arrivals = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [prefix, rng.integers(0, vocab_size, suffixes[i])
                         .astype(np.int32)]),
                    max_new_tokens=int(buds[i]),
                    t_arrival=float(arrivals[i]))
            for i in range(n)]


def multi_prefix_requests(rng: np.random.Generator, n: int, vocab_size: int,
                          n_prefixes: int = 4, prefix_len: int = 48,
                          suffix_range: Tuple[int, int] = (3, 9),
                          budgets: Union[int, Tuple[int, int]] = (16, 48),
                          rate: float = 0.0) -> List[Request]:
    """n requests drawn over ``n_prefixes`` distinct shared system prompts
    (uniform random assignment) — the multi-tenant ingress the fleet
    router's prefix-affinity dispatch targets: each prefix group hits one
    replica's radix tree under affinity routing, while round-robin pays a
    cold prefill per (replica, prefix) pair (docs/fleet.md)."""
    prefixes = [rng.integers(0, vocab_size, prefix_len).astype(np.int32)
                for _ in range(n_prefixes)]
    which = rng.integers(0, n_prefixes, n)
    suffixes = rng.integers(suffix_range[0], suffix_range[1], n)
    if isinstance(budgets, tuple):
        buds = rng.integers(budgets[0], budgets[1], n)
    else:
        buds = np.full(n, budgets)
    gaps = (rng.exponential(1.0 / rate, n) if rate > 0 else np.zeros(n))
    arrivals = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [prefixes[which[i]],
                         rng.integers(0, vocab_size, suffixes[i])
                         .astype(np.int32)]),
                    max_new_tokens=int(buds[i]),
                    t_arrival=float(arrivals[i]))
            for i in range(n)]


def bursty_requests(rng: np.random.Generator, n: int, vocab_size: int,
                    short_range: Tuple[int, int] = (8, 16),
                    long_range: Tuple[int, int] = (180, 240),
                    burst_every: int = 8, burst_size: int = 4,
                    budgets: Union[int, Tuple[int, int]] = (6, 12),
                    rate: float = 0.0) -> List[Request]:
    """Phase-skewed arrivals: steady short-prompt decode traffic with
    long-prompt *bursts* injected every ``burst_every`` steady arrivals
    (``burst_size`` long prompts land at the same instant).  This is the
    ingress shape that exposes the colocated prefill-stall pathology —
    each burst member costs a large-bucket prefill dispatch, and every
    short request queued behind the burst pays that bill in TTFT — and
    the one the disaggregated prefill/decode pools are measured on
    (benchmarks/run.py serve_disagg, docs/perf.md §TTFT under burst).

    Short vs long is classifiable from ``len(prompt)`` alone (the ranges
    must not overlap); total request count is exactly ``n``.  Purely
    rng-driven, so a stream is deterministic under `clone_requests`."""
    if short_range[1] > long_range[0]:
        raise ValueError("bursty_requests: short_range and long_range "
                         "overlap — burst membership must be classifiable "
                         "from prompt length")
    reqs: List[Request] = []
    t = 0.0
    steady = 0
    while len(reqs) < n:
        if steady and steady % burst_every == 0:
            # a burst: `burst_size` long prompts at this instant
            for _ in range(min(burst_size, n - len(reqs))):
                ln = int(rng.integers(long_range[0], long_range[1]))
                bud = (int(rng.integers(budgets[0], budgets[1]))
                       if isinstance(budgets, tuple) else int(budgets))
                reqs.append(Request(
                    rid=len(reqs),
                    prompt=rng.integers(0, vocab_size, ln).astype(np.int32),
                    max_new_tokens=bud, t_arrival=t))
            steady += 1  # one burst per boundary, then steady resumes
            continue
        t += float(rng.exponential(1.0 / rate)) if rate > 0 else 0.0
        ln = int(rng.integers(short_range[0], short_range[1]))
        bud = (int(rng.integers(budgets[0], budgets[1]))
               if isinstance(budgets, tuple) else int(budgets))
        reqs.append(Request(
            rid=len(reqs),
            prompt=rng.integers(0, vocab_size, ln).astype(np.int32),
            max_new_tokens=bud, t_arrival=t))
        steady += 1
    return reqs


def clone_requests(reqs: List[Request]) -> List[Request]:
    """Fresh Request objects over the same prompts/budgets/arrivals (for
    replaying one stream through several engines)."""
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id,
                    t_arrival=r.t_arrival) for r in reqs]


def replay(engine, stream: List[Request], warmup: bool = True):
    """Run `stream` through `engine`; returns (done, wall_s, tok_s, ttft_ms).

    warmup=True first replays the stream unmeasured so every program shape
    is compiled, then measures a steady-state pass.  ttft_ms is the list of
    per-request first-token latencies (measured from simulated arrival).
    """
    if warmup:
        for r in clone_requests(stream):
            engine.submit(r)
        engine.run()
    for r in clone_requests(stream):
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens_out) for r in done)
    ttft = [(r.t_first_token - r.t_enqueue) * 1e3 for r in done]
    return done, wall, toks / wall, ttft
