"""Fleet front door: prefix-affinity dispatch over N engine replicas.

`FleetRouter` owns N `Replica`s (serving/replica.py — independent
`ContinuousBatchingEngine`s, each with its own serve plan, paged arena
and radix prefix tree) and places every incoming request on exactly one
of them.  Streams are bit-identical to single-replica serving by
construction: a replica *is* the single-process engine, greedy decode is
deterministic, and the router only ever chooses *where* a request runs.

Placement policies (`FleetConfig.route`):

  affinity     the router-side radix index maps the request's longest
               previously-routed prefix to the replica whose tree should
               hold it; cold prompts fall back to least-loaded.  The
               index is *advisory*: it records where a prefix was sent,
               not whether the replica still caches it (LRU eviction is
               replica-local), so a stale entry costs one cold prefill —
               never an error (docs/fleet.md §affinity index).
  least-loaded argmin over `Scheduler.projected_occupancy()` — queued
               work in token-steps, not request count, so one 2k-token
               prompt outweighs ten chat turns.
  round-robin  the control arm: rotate, ignore both signals.

Deadline-aware balancing: an affinity hit is overridden when the target
replica's backlog exceeds the least-loaded replica's by more than
`rebalance_margin` token-steps — past that, the skipped prefill can't
pay back the added queue wait against the engine's admission deadline.

Load shedding: when **every** replica's admission queue is at least
`shed_depth x shed_budget` requests deep, the request is rejected with a
reason string instead of being queued (`RouteDecision.kind == "shed"`).
Shedding at the door keeps the per-replica deadline machinery meaningful:
an unbounded router queue would just convert overload into timeouts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.replica import Replica
from repro.serving.scheduler import Request

ROUTE_POLICIES = ("affinity", "round-robin", "least-loaded")


@dataclass(frozen=True)
class FleetConfig:
    route: str = "affinity"
    # shedding: reject when every replica queues >= shed_depth*shed_budget
    # requests; 0 disables (the bench's closed streams never shed)
    shed_depth: int = 0
    shed_budget: float = 1.0
    # affinity override threshold, in projected-occupancy token-steps
    rebalance_margin: int = 256
    # affinity-index granularity (tokens per trie edge); match the
    # replicas' page_size so index hits line up with tree hits
    index_block: int = 16

    def __post_init__(self):
        if self.route not in ROUTE_POLICIES:
            raise ValueError(f"route {self.route!r} not in {ROUTE_POLICIES}")
        if self.index_block < 1:
            raise ValueError("index_block must be >= 1")

    @property
    def shed_limit(self) -> int:
        return (math.ceil(self.shed_depth * self.shed_budget)
                if self.shed_depth > 0 else 0)


@dataclass
class RouteDecision:
    rid: int
    replica: Optional[int]        # None iff shed
    kind: str                     # affinity|least-loaded|round-robin|
                                  # rebalanced|shed
    expected_hit_tokens: int = 0  # index-side match (advisory, see docs)
    reason: str = ""              # shed reason; empty otherwise


class _Node:
    __slots__ = ("children", "replica")

    def __init__(self):
        self.children: Dict[bytes, _Node] = {}
        self.replica: int = -1


class AffinityIndex:
    """Router-side radix index over `block`-token prompt chunks.

    Distinct from the replicas' `RadixPrefixCache`: no pages, no
    refcounts, no eviction — each trie edge is one block of tokens and
    each node remembers the replica most recently *sent* a prompt
    through it (last-writer-wins keeps the index pointing at the replica
    with the freshest copy).  Lookups cap the match at len-1 tokens,
    mirroring the tree's always-re-ingest-the-last-token rule, so
    `expected_hit_tokens` is comparable to engine `prefix_hit_tokens`.
    """

    def __init__(self, block: int):
        self.block = block
        self.root = _Node()
        self.nodes = 0

    def _key(self, tokens: np.ndarray, j: int) -> bytes:
        b = self.block
        return np.ascontiguousarray(
            tokens[j * b:(j + 1) * b], dtype=np.int32).tobytes()

    def lookup(self, tokens: np.ndarray) -> Tuple[int, int]:
        """(replica, matched_tokens) for the longest indexed block-aligned
        prefix; (-1, 0) when no full block matches."""
        max_blocks = max(len(tokens) - 1, 0) // self.block
        node, depth = self.root, 0
        for j in range(max_blocks):
            child = node.children.get(self._key(tokens, j))
            if child is None:
                break
            node, depth = child, j + 1
        if node is self.root:
            return -1, 0
        return node.replica, depth * self.block

    def insert(self, tokens: np.ndarray, replica: int) -> None:
        node = self.root
        for j in range(len(tokens) // self.block):
            key = self._key(tokens, j)
            child = node.children.get(key)
            if child is None:
                child = _Node()
                node.children[key] = child
                self.nodes += 1
            child.replica = replica
            node = child


class FleetRouter:
    """N replicas behind one `submit()`/`run()` pair (the plain engine's
    own surface, so callers swap a fleet in without code changes).

    `run()` drains the replicas **sequentially** — in-process replicas
    share the host, so the fleet measures placement quality (hit rates,
    skipped prefills, shed counts), not wall-clock parallelism; a
    multi-process fleet would run the same routing with concurrent
    drains (docs/fleet.md §what the bench measures).
    """

    def __init__(self,
                 replicas: Sequence[Union[Replica,
                                          ContinuousBatchingEngine]],
                 config: Optional[FleetConfig] = None):
        if not replicas:
            raise ValueError("FleetRouter needs at least one replica")
        self.replicas: List[Replica] = [
            r if isinstance(r, Replica) else Replica(i, r)
            for i, r in enumerate(replicas)]
        self.config = config or FleetConfig()
        self.index = AffinityIndex(self.config.index_block)
        self.decisions: List[RouteDecision] = []
        self.shed: List[Tuple[Request, str]] = []
        self._rr_next = 0

    # -- placement -----------------------------------------------------------

    def _least_loaded(self) -> int:
        occ = [rep.projected_occupancy() for rep in self.replicas]
        return min(range(len(occ)), key=lambda i: (occ[i], i))

    def _shed_reason(self) -> Optional[str]:
        limit = self.config.shed_limit
        if limit and all(rep.queue_depth() >= limit
                         for rep in self.replicas):
            return (f"all {len(self.replicas)} replicas saturated: "
                    f"admission queues >= {limit} "
                    f"(depth {self.config.shed_depth} x budget "
                    f"{self.config.shed_budget:g})")
        return None

    def route(self, req: Request) -> RouteDecision:
        """Pick a replica (or shed) without submitting — the policy in
        isolation, for tests and dry inspection."""
        reason = self._shed_reason()
        if reason is not None:
            return RouteDecision(rid=req.rid, replica=None, kind="shed",
                                 reason=reason)
        mode = self.config.route
        if mode == "round-robin":
            t = self._rr_next % len(self.replicas)
            return RouteDecision(rid=req.rid, replica=t, kind="round-robin")
        if mode == "least-loaded":
            return RouteDecision(rid=req.rid, replica=self._least_loaded(),
                                 kind="least-loaded")
        target, hit = self.index.lookup(req.prompt)
        if target < 0:
            return RouteDecision(rid=req.rid, replica=self._least_loaded(),
                                 kind="least-loaded")
        least = self._least_loaded()
        lag = (self.replicas[target].projected_occupancy()
               - self.replicas[least].projected_occupancy())
        if least != target and lag > self.config.rebalance_margin:
            return RouteDecision(rid=req.rid, replica=least,
                                 kind="rebalanced", expected_hit_tokens=0)
        return RouteDecision(rid=req.rid, replica=target, kind="affinity",
                             expected_hit_tokens=hit)

    def submit(self, req: Request) -> RouteDecision:
        dec = self.route(req)
        self.decisions.append(dec)
        if dec.kind == "shed":
            self.shed.append((req, dec.reason))
            return dec
        if dec.kind == "round-robin":
            self._rr_next += 1
        if self.config.route == "affinity":
            self.index.insert(req.prompt, dec.replica)
        self.replicas[dec.replica].submit(req)
        return dec

    # -- serving -------------------------------------------------------------

    def run(self) -> List[Request]:
        """Drain every replica; completed requests sorted by rid (the
        engine's own contract).  Shed requests are *not* in the result —
        read `router.shed` for them."""
        done: List[Request] = []
        for rep in self.replicas:
            done.extend(rep.run())
        return sorted(done, key=lambda r: r.rid)

    # -- accounting ----------------------------------------------------------

    def stats(self) -> Dict:
        by_kind: Dict[str, int] = {}
        exp_hit = 0
        for d in self.decisions:
            by_kind[d.kind] = by_kind.get(d.kind, 0) + 1
            exp_hit += d.expected_hit_tokens
        per = [rep.stats() for rep in self.replicas]
        return {
            "route": self.config.route,
            "submitted": len(self.decisions),
            "shed": len(self.shed),
            "by_kind": by_kind,
            "expected_hit_tokens": exp_hit,
            "index_nodes": self.index.nodes,
            "prefix_hits": sum(p.get("prefix_hits", 0) for p in per),
            "prefix_hit_tokens": sum(p.get("prefix_hit_tokens", 0)
                                     for p in per),
            "replicas": per,
        }


def build_fleet(model, params, n: int, *,
                plans: Optional[Sequence] = None,
                config: Optional[FleetConfig] = None,
                **engine_kw) -> FleetRouter:
    """N fresh engines (shared read-only model/params, per-replica plan)
    behind one router.  `plans[i]` places replica i on its device group
    (serving/replica.py `replica_device_groups` + `make_group_mesh`);
    None serves every replica from the default device."""
    plans = list(plans) if plans is not None else [None] * n
    if len(plans) != n:
        raise ValueError(f"fleet: {n} replicas but {len(plans)} plans")
    engines = [ContinuousBatchingEngine(model, params, plan=plans[i],
                                        **engine_kw)
               for i in range(n)]
    return FleetRouter([Replica(i, e) for i, e in enumerate(engines)],
                       config)
