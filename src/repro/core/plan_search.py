"""Cost-model-driven serve-plan auto-search (ROADMAP: plan auto-search).

The paper's Cluster Builder (§6) maps a transformer onto the platform
*before* anything is deployed, and its pipeline latency model (§8.2.2,
Eq. 1: T_total = T + (L-1)(X+d)) prices each mapping.  This module plays
both roles for the TPU reproduction: it enumerates serve-plan candidates
over TP width x pipeline stage depth x `exact` x `page_size` x
`kv_dtype` x `quant_weights`, scores each against a declared traffic
profile with a cost model composed from

  * roofline/jaxpr_cost  — per-block FLOPs/bytes counted from the traced
    decode step (exact, deterministic, scan-trip-aware), with an
    active/total parameter correction for MoE (the trace runs every
    expert dense);
  * roofline/analysis    — the v5e peaks (bf16/int8 FLOP/s, HBM and ICI
    bandwidth) that turn counts into seconds;
  * core/latency_model   — Eq. 1 fill math for pipeline TTFT (X ~= 0.53 T,
    the paper's §9 Versal fit) and the ticks-per-step schedules of the
    drained (exact) vs request-skewed (throughput) pipelines;
  * serving/kv_manager   — `kv_page_bytes` / `num_pages_for_hbm` for HBM
    feasibility: a candidate whose weights + KV pool exceed the profile's
    per-device budget is pruned, never chosen.

The output is a Pareto frontier (maximise tok/s, minimise TTFT, minimise
HBM pressure) plus a single deterministic choice, realisable as a
`ClusterPlan` via `realize()` and printable with `launch/serve.py
--plan auto --traffic <profile.json> --dryrun`.

Trust machinery (docs/perf.md §cost model): chosen plans per config
family are snapshotted under `benchmarks/plans/` and diffed in CI
(`benchmarks/run.py plan_search --check-plans`), and serve benches stamp
the model's *predicted* tok/s next to measured so perf.yml can gate the
ratio — see `DeviceCalibration` / `predict_engine_tok_s` at the bottom.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from functools import lru_cache
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.latency_model import (StageTiming, pipeline_ticks_per_step,
                                      total_latency)
from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                     PEAK_FLOPS_INT8)
from repro.serving.kv_manager import kv_page_bytes, num_pages_for_hbm

# Bump when the scoring math changes shape: snapshots embed it so a plan
# drift caused by a cost-model revision is distinguishable from one
# caused by a config/profile edit.
# v2: replicas became an enumerated candidate axis (the fleet router's
# TP-width-vs-replica-count trade) instead of the implicit devices//width.
# v3: disaggregated prefill:decode splits became an enumerated axis —
# priced with a shipped-bytes-per-admission transfer term (paper hop
# latency) and prefill-pool queueing, pruned when either pool saturates.
COST_MODEL_VERSION = 3

PAGE_SIZES = (8, 16, 32)
KV_DTYPES = ("bf16", "int8")

# Paper §9 Versal fit: time-to-first-output X ~= 0.53 T at seq 128; we
# reuse it for pipeline prefill fill (Eq. 1 needs an X and the stages
# stream activations exactly like the paper's encoder clusters).
X_FRACTION = 0.53

# Fraction of the per-device HBM budget reserved for activations,
# dispatch scratch and allocator slack before the KV pool is sized.
ACT_SLACK_FRAC = 0.05

# int8 weight bytes per parameter (1 B value + amortised f32 scale).
INT8_WEIGHT_BYTES = 1.05


class PlanSearchError(ValueError):
    pass


# ---------------------------------------------------------------------------
# traffic profile
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficProfile:
    """The declared workload + platform budget a plan is searched for.

    JSON schema (docs/serving.md §plan auto-search) mirrors the field
    names 1:1; unknown keys are rejected so a typo'd profile cannot
    silently search the defaults.
    """
    name: str = "default"
    arrival_rate: float = 8.0     # offered requests/s
    prompt_mean: float = 128.0    # tokens
    prompt_max: int = 256
    output_mean: float = 128.0    # tokens
    output_max: int = 256
    devices: int = 8              # declared device budget (not the host's)
    hbm_gb: float = 16.0          # per-device HBM budget
    max_batch: int = 32           # scheduler lane cap per replica
    ttft_target_ms: float = 0.0   # 0 = unconstrained

    @property
    def hbm_bytes(self) -> int:
        return int(self.hbm_gb * (1 << 30))

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TrafficProfile":
        known = {f for f in cls.__dataclass_fields__}
        unknown = sorted(set(d) - known)
        if unknown:
            raise PlanSearchError(
                f"unknown traffic-profile keys {unknown}; "
                f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, path: str) -> "TrafficProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareModel:
    """Per-chip peaks + the paper's inter-stage hop d (Table 1: 1.1 us)."""
    peak_flops: float = PEAK_FLOPS_BF16
    peak_flops_int8: float = PEAK_FLOPS_INT8
    hbm_bw: float = HBM_BW
    link_bw: float = ICI_BW
    hop_s: float = 1.1e-6         # Eq. 1's d
    dispatch_s: float = 50e-6     # host->device program launch overhead

    def peak(self, quant_weights: bool) -> float:
        return self.peak_flops_int8 if quant_weights else self.peak_flops


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------


@dataclass(frozen=True, order=True)
class Candidate:
    mode: str                 # "serve" | "serve_pipeline"
    tp: int = 1               # model-axis width      (mode="serve")
    stages: int = 1           # stage-axis depth      (mode="serve_pipeline")
    exact: bool = True
    page_size: int = 16       # 0 = dense slot table (exact pipeline)
    kv_dtype: str = "bf16"
    quant_weights: bool = False
    replicas: int = 1         # independent engines behind the fleet router
    # disaggregated pools (engine disagg=(P, D)): device counts for the
    # prefill and decode pools; (0, 0) = colocated.  Disagg candidates
    # ride mode="serve" tp=1 (each pool replicates the model in-process —
    # docs/serving.md §disaggregated serving).
    disagg_prefill: int = 0
    disagg_decode: int = 0

    @property
    def width(self) -> int:
        """Devices one replica occupies."""
        if self.disagg_prefill:
            return self.disagg_prefill + self.disagg_decode
        return self.tp if self.mode == "serve" else self.stages

    @property
    def paged(self) -> bool:
        return self.page_size > 0

    @property
    def key(self) -> str:
        core = (f"serve.tp{self.tp}" if self.mode == "serve"
                else f"pipe.s{self.stages}")
        if self.disagg_prefill:
            core += f".pd{self.disagg_prefill}-{self.disagg_decode}"
        ex = "exact" if self.exact else "tput"
        kv = ("kv=dense" if not self.paged
              else f"kv=ps{self.page_size}.{self.kv_dtype}")
        w = "w=int8" if self.quant_weights else "w=bf16"
        return f"{core}.r{self.replicas}.{ex}.{kv}.{w}"


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_candidates(cfg, profile: TrafficProfile) -> List[Candidate]:
    """The search grid the tentpole declares, canonicalised:

    * serve: tp over divisors of the device budget; tp=1 has no
      gather/psum distinction so only exact=True is emitted.
    * replicas: for each width, every divisor count the budget covers
      (replicas x width <= devices) — the explicit TP-width-vs-replica-
      count trade the fleet router serves (serving/router.py).  Fewer
      replicas than the budget allows is enumerable (a fleet may reserve
      devices) but is dominated at fixed width, so the frontier documents
      the trade instead of hiding it in an implicit devices//width.
    * serve_pipeline: stage depths over divisors >= 2 whose layer stack
      divides (cluster_builder shards the scan dim; a non-dividing depth
      replicates and is never worth enumerating).  exact pipelines
      stream the dense slot path (page_size=0, bf16 cache — executor
      asserts paged is off); throughput (request-skewed) pipelines run
      the stage-local paged arena.
    * int8 KV requires the paged arena (engine guard), so dense slots
      are bf16-only; quant_weights composes with everything.
    * disagg: every prefill:decode split of the device budget (P >= 1,
      D = devices - P), paged tp=1 only — page shipping is the handoff
      unit and each pool replicates the model.  Priced with the transfer
      + queueing terms in `score_candidate`; today's in-process pools
      never beat colocated tp=1 on TTFT alone, so these document the
      trade on the frontier rather than win it (the device-parallel win
      arrives with multi-process fleets — docs/serving.md).
    """
    from repro.models.transformer import period_length
    cands: List[Candidate] = []
    for tp in _divisors(profile.devices):
        exacts = (True,) if tp == 1 else (True, False)
        for rep in _divisors(profile.devices // tp):
            for exact in exacts:
                for ps in PAGE_SIZES:
                    for kvd in KV_DTYPES:
                        for qw in (False, True):
                            cands.append(Candidate(
                                mode="serve", tp=tp, exact=exact,
                                page_size=ps, kv_dtype=kvd,
                                quant_weights=qw, replicas=rep))
    stack = cfg.n_layers // period_length(cfg)
    for s in _divisors(profile.devices):
        if s < 2 or stack % s:
            continue
        for rep in _divisors(profile.devices // s):
            for qw in (False, True):
                cands.append(Candidate(mode="serve_pipeline", stages=s,
                                       exact=True, page_size=0,
                                       kv_dtype="bf16", quant_weights=qw,
                                       replicas=rep))
                for ps in PAGE_SIZES:
                    for kvd in KV_DTYPES:
                        cands.append(Candidate(
                            mode="serve_pipeline", stages=s, exact=False,
                            page_size=ps, kv_dtype=kvd, quant_weights=qw,
                            replicas=rep))
    for p in range(1, profile.devices):
        d = profile.devices - p
        for ps in PAGE_SIZES:
            for kvd in KV_DTYPES:
                for qw in (False, True):
                    cands.append(Candidate(
                        mode="serve", tp=1, exact=True, page_size=ps,
                        kv_dtype=kvd, quant_weights=qw, replicas=1,
                        disagg_prefill=p, disagg_decode=d))
    return sorted(set(cands))


# ---------------------------------------------------------------------------
# traced block costs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockCosts:
    """Affine decode-step cost decomposition from two jaxpr traces:

        cost(B) = fixed + B * per_lane

    `fixed` is dominated by the weight stream (every decode step reads
    every live parameter once), `per_lane` by the KV read + activation
    math of one resident lane.  `moe_active_frac` scales matmul FLOPs
    down to the routed share (the trace runs all experts dense).
    """
    flops_fixed: float
    flops_per_lane: float
    bytes_fixed: float
    bytes_per_lane: float
    prefill_flops_per_tok: float   # per prompt token, full model
    weight_bytes_bf16: float       # analytic live-parameter bytes
    moe_active_frac: float


@lru_cache(maxsize=None)
def block_costs(cfg, cache_len: int = 512) -> BlockCosts:
    """Trace `Model.decode_step` at two batch sizes on ShapeDtypeStructs
    (cheap even for 14B+ configs: jaxpr counting never materialises
    weights) and fit the affine decomposition."""
    import jax

    from repro.models.transformer import init_params, make_model
    from repro.roofline.jaxpr_cost import count_costs

    model = make_model(cfg)
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))

    def costs_at(batch: int):
        caches = jax.eval_shape(lambda: model.init_cache(batch, cache_len))
        token = jax.ShapeDtypeStruct((batch,), "int32")
        c = count_costs(lambda p, ca, t: model.decode_step(p, ca, t)[0],
                        params, caches, token)
        return c["flops"], c["bytes"]

    b_lo, b_hi = 1, 5
    f_lo, by_lo = costs_at(b_lo)
    f_hi, by_hi = costs_at(b_hi)
    f_lane = max((f_hi - f_lo) / (b_hi - b_lo), 0.0)
    by_lane = max((by_hi - by_lo) / (b_hi - b_lo), 0.0)
    act = cfg.active_param_count() / max(cfg.param_count(), 1)
    return BlockCosts(
        flops_fixed=max(f_lo - b_lo * f_lane, 0.0),
        flops_per_lane=f_lane,
        bytes_fixed=max(by_lo - b_lo * by_lane, 0.0),
        bytes_per_lane=by_lane,
        prefill_flops_per_tok=2.0 * cfg.active_param_count(),
        weight_bytes_bf16=2.0 * cfg.param_count(),
        moe_active_frac=act,
    )


def _reduction_frac(cfg) -> float:
    """Share of block matmul params living in *reduction* projections
    (attention output + FFN down): the mats gather-form exact TP
    replicates, so their FLOPs/bytes do not shrink with tp."""
    d, ff = cfg.d_model, cfg.d_ff
    attn_out = cfg.n_heads * cfg.head_dim * d
    attn_in = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
    ffn_down = ff * d
    ffn_up = (2 * d * ff if cfg.mlp_style == "swiglu" else d * ff)
    red = attn_out + ffn_down
    tot = red + attn_in + ffn_up
    return red / max(tot, 1)


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------


@dataclass
class Score:
    cand: Candidate
    feasible: bool
    reason: str = ""               # why infeasible (empty when feasible)
    tok_s: float = 0.0             # predicted aggregate tokens/s (all replicas)
    ttft_ms: float = 0.0           # predicted cold time-to-first-token
    step_ms: float = 0.0           # one decode tick at the operating batch
    hbm_frac: float = 0.0          # per-device HBM used / budget
    lanes: int = 0                 # resident lanes per replica at steady state
    replicas: int = 1
    kv_pages: int = 0              # pool size per replica (0 = dense slots)
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def key(self) -> str:
        return self.cand.key


def _infeasible(cand: Candidate, reason: str) -> Score:
    return Score(cand=cand, feasible=False, reason=reason)


def _kv_shards(cfg, cand: Candidate) -> int:
    """How many ways the KV arena actually divides per device.  serve
    shards over kv heads (falls back to replication when tp does not
    divide, mirroring cluster_builder's Rules); throughput pipelines
    stage-shard the layer stack (kv_manager's shards= semantics)."""
    if cand.mode == "serve":
        return cand.tp if cfg.n_kv_heads % cand.tp == 0 else 1
    if not cand.exact:
        return cand.stages
    return cand.stages  # exact pipeline: scan-stacked dense cache shards


def score_candidate(cfg, cand: Candidate, profile: TrafficProfile,
                    hw: HardwareModel, costs: BlockCosts) -> Score:
    w = cand.width
    if w > profile.devices:
        return _infeasible(cand, "wider than device budget")
    replicas = cand.replicas
    if replicas * w > profile.devices:
        return _infeasible(cand, "replica fleet exceeds device budget")
    disagg = cand.disagg_prefill > 0
    if disagg:
        # both pools replicate the full model in-process, so every
        # per-device quantity below is the tp=1 figure; the split's own
        # costs (transfer + prefill queueing) are priced after TTFT
        w = 1

    # ---- HBM feasibility: weights first, then the KV pool -----------------
    wbytes_per_param = (INT8_WEIGHT_BYTES if cand.quant_weights else 2.0)
    weight_total = cfg.param_count() * wbytes_per_param
    embed_bytes = cfg.embed_params() * wbytes_per_param
    if cand.mode == "serve":
        red = _reduction_frac(cfg) if cand.exact and w > 1 else 0.0
        weight_dev = weight_total * ((1 - red) / w + red)
    else:
        # stage s holds its layer slice; embeddings ride on first/last
        # stage but budget them everywhere (conservative).
        weight_dev = (weight_total - embed_bytes) / w + embed_bytes
    budget = profile.hbm_bytes * (1.0 - ACT_SLACK_FRAC)
    kv_budget = budget - weight_dev
    if kv_budget <= 0:
        return _infeasible(
            cand, f"weights alone need {weight_dev / 1e9:.1f} GB/device "
                  f"(budget {budget / 1e9:.1f} GB)")

    seq_cap = profile.prompt_max + profile.output_max
    shards = _kv_shards(cfg, cand)
    if cand.paged:
        pages = num_pages_for_hbm(cfg, cand.page_size, cand.kv_dtype,
                                  int(kv_budget), shards=shards)
        pages_per_lane = -(-seq_cap // cand.page_size) + 1
        lanes_cap = max((pages - 1) // pages_per_lane, 0)  # -1: trash page
        lane_bytes = pages_per_lane * kv_page_bytes(
            cfg, cand.page_size, cand.kv_dtype, shards=shards)
    else:
        pages = 0
        per_row = 2 * cfg.n_kv_heads * cfg.head_dim * 2  # k+v bf16
        lane_bytes = cfg.n_layers * seq_cap * per_row / shards
        lanes_cap = int(kv_budget // lane_bytes)
    if lanes_cap < 1:
        return _infeasible(
            cand, f"KV pool cannot hold one {seq_cap}-token lane "
                  f"({lane_bytes / 1e6:.0f} MB/lane, "
                  f"{max(kv_budget, 0) / 1e6:.0f} MB free)")
    lanes_cap = min(lanes_cap, profile.max_batch)
    if cand.mode == "serve_pipeline" and not cand.exact:
        # request-skewed schedule groups lanes per stage
        lanes_cap = max((lanes_cap // cand.stages) * cand.stages, 0)
        if lanes_cap < cand.stages:
            return _infeasible(
                cand, "fewer KV lanes than stages (skewed schedule needs "
                      "one lane group per stage)")

    # ---- decode tick time at batch B --------------------------------------
    peak = hw.peak(cand.quant_weights)
    act = costs.moe_active_frac
    red = (_reduction_frac(cfg)
           if cand.mode == "serve" and cand.exact and w > 1 else 0.0)
    wscale = wbytes_per_param / 2.0   # traced bytes assume 2 B weights
    kvscale = (0.55 if cand.kv_dtype == "int8" else 1.0)

    def tick_s(batch: int) -> float:
        flops = (costs.flops_fixed + batch * costs.flops_per_lane) * act
        byts = (costs.bytes_fixed * wscale
                + batch * costs.bytes_per_lane * kvscale)
        # per-device share: reduction mats replicate under gather-form TP
        f_dev = flops * ((1 - red) / w + red)
        b_dev = byts * ((1 - red) / w + red)
        t = max(f_dev / peak, b_dev / hw.hbm_bw)
        row = batch * cfg.d_model * 2  # one activation row, bf16
        if cand.mode == "serve" and w > 1:
            sites = 2 * cfg.n_layers       # attn + ffn reduction points
            per_site = (row * (w - 1) / w) * (1 if cand.exact else 2)
            t += sites * (per_site / hw.link_bw + hw.hop_s)
        elif cand.mode == "serve_pipeline":
            # t is already the per-stage slice (f_dev/b_dev divide by w)
            ticks = pipeline_ticks_per_step(w, exact=cand.exact)
            hop = hw.hop_s + (row / w) / hw.link_bw
            return ticks * (t + hop)
        return t

    # ---- operating point: Little's-law fixed point ------------------------
    per_replica_rate = profile.arrival_rate / replicas
    lanes = max(min(lanes_cap, profile.max_batch), 1)
    for _ in range(8):
        t = tick_s(lanes) + hw.dispatch_s / 8.0   # horizon-8 amortised
        demand = per_replica_rate * profile.output_mean * t
        lanes = max(1, min(lanes_cap, int(math.ceil(demand))))
    step = tick_s(lanes) + hw.dispatch_s / 8.0
    tok_s = replicas * lanes / step

    # ---- TTFT: prefill + (pipeline) Eq. 1 fill ----------------------------
    pre_flops = costs.prefill_flops_per_tok * profile.prompt_mean
    pre_bytes = costs.weight_bytes_bf16 * wscale
    t_pre_dev = max((pre_flops / w) / peak, (pre_bytes / w) / hw.hbm_bw)
    if cand.mode == "serve_pipeline":
        fill = StageTiming(T=t_pre_dev, X=X_FRACTION * t_pre_dev,
                           d=hw.hop_s)
        ttft = total_latency(fill, w) + hw.dispatch_s
    else:
        ttft = t_pre_dev + hw.dispatch_s
    ttft += step  # first decoded token rides the next tick

    if disagg:
        # ---- disaggregated pools: transfer + queueing -----------------
        # Shipped bytes per cold admission: the prompt's pages (int8
        # arenas ship their scale planes too — kv_page_bytes counts them)
        # cross the pool link once, plus the paper's hop latency (Eq. 1's
        # d) and one ingest dispatch on the decode pool.
        p_pool = cand.disagg_prefill
        n_ship = max(int(math.ceil(profile.prompt_mean / cand.page_size)),
                     1)
        ship_bytes = n_ship * kv_page_bytes(cfg, cand.page_size,
                                            cand.kv_dtype, shards=1)
        t_ship = ship_bytes / hw.link_bw + hw.hop_s
        # Prefill pool: P parallel workers (the multi-process form; the
        # in-process pools serialise on the host, docs/serving.md), each
        # an M/M/1 at rate lambda/P with service = one bucketed prefill.
        rho = per_replica_rate * t_pre_dev / p_pool
        if rho >= 1.0:
            return _infeasible(
                cand, f"prefill pool saturated (util {rho:.2f} at "
                      f"{p_pool} prefill devices)")
        ingest = per_replica_rate * (t_ship + hw.dispatch_s)
        if ingest >= 1.0:
            return _infeasible(
                cand, "decode pool saturated by page ingest")
        tok_s *= 1.0 - ingest     # decode time lost to ingest dispatches
        ttft += t_ship + rho / (1.0 - rho) * t_pre_dev

    if cand.paged:
        # pool the engine would allocate: full residency for the lane
        # cap plus the trash page (engine default sizing), never more
        # than the budget buys
        pool_pages = min(pages, lanes_cap * pages_per_lane + 1)
        kv_used = pool_pages * kv_page_bytes(
            cfg, cand.page_size, cand.kv_dtype, shards=shards)
    else:
        pool_pages = 0
        kv_used = lanes_cap * lane_bytes
    hbm_used = weight_dev + kv_used
    detail = {"weight_gb_dev": weight_dev / 1e9,
              "lanes_cap": float(lanes_cap),
              "tick_ms": tick_s(lanes) * 1e3}
    if disagg:
        detail.update(ship_bytes_adm=float(ship_bytes),
                      t_ship_us=t_ship * 1e6, prefill_util=rho)
    return Score(
        cand=cand, feasible=True, tok_s=tok_s, ttft_ms=ttft * 1e3,
        step_ms=step * 1e3, hbm_frac=hbm_used / profile.hbm_bytes,
        lanes=lanes, replicas=replicas, kv_pages=pool_pages,
        detail=detail,
    )


# ---------------------------------------------------------------------------
# pareto + choice
# ---------------------------------------------------------------------------


def _dominates(a: Score, b: Score) -> bool:
    ge = (a.tok_s >= b.tok_s and a.ttft_ms <= b.ttft_ms
          and a.hbm_frac <= b.hbm_frac)
    gt = (a.tok_s > b.tok_s or a.ttft_ms < b.ttft_ms
          or a.hbm_frac < b.hbm_frac)
    return ge and gt


def pareto_frontier(scores: Sequence[Score]) -> List[Score]:
    feas = [s for s in scores if s.feasible]
    front = [s for s in feas
             if not any(_dominates(o, s) for o in feas if o is not s)]
    return sorted(front, key=lambda s: (-s.tok_s, s.ttft_ms, s.key))


def choose(scores: Sequence[Score],
           profile: TrafficProfile) -> Optional[Score]:
    """Deterministic winner: feasible, meets the TTFT target when one is
    declared (falls back to min-TTFT if nothing does), then max tok/s,
    tie-broken by lower TTFT, lower HBM, candidate key."""
    feas = [s for s in scores if s.feasible]
    if not feas:
        return None
    pool = feas
    if profile.ttft_target_ms > 0:
        meeting = [s for s in feas if s.ttft_ms <= profile.ttft_target_ms]
        pool = meeting or sorted(feas, key=lambda s: (s.ttft_ms, s.key))[:1]
    return sorted(pool, key=lambda s: (-s.tok_s, s.ttft_ms,
                                       s.hbm_frac, s.key))[0]


@dataclass
class SearchResult:
    profile: TrafficProfile
    scores: List[Score]
    frontier: List[Score]
    chosen: Optional[Score]

    @property
    def n_feasible(self) -> int:
        return sum(1 for s in self.scores if s.feasible)


def search(cfg, profile: TrafficProfile,
           hw: Optional[HardwareModel] = None) -> SearchResult:
    hw = hw or HardwareModel()
    costs = block_costs(cfg)
    scores = [score_candidate(cfg, c, profile, hw, costs)
              for c in enumerate_candidates(cfg, profile)]
    return SearchResult(profile=profile, scores=scores,
                        frontier=pareto_frontier(scores),
                        chosen=choose(scores, profile))


def realize(cfg, score: Score, mesh=None):
    """Turn the chosen Score into a ClusterPlan.  With mesh=None an
    AbstractMesh of the candidate's shape is built (enough for --dryrun
    sharding inspection); pass a real mesh to deploy."""
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_abstract_mesh
    cand = score.cand
    if cand.disagg_prefill:
        raise PlanSearchError(
            "disagg candidates own their device placement (no ClusterPlan "
            "to realize); deploy with launch/serve.py --disagg "
            f"{cand.disagg_prefill}:{cand.disagg_decode} --plan none")
    if mesh is None:
        if cand.mode == "serve":
            mesh = make_abstract_mesh(
                (score.replicas, cand.tp), ("data", "model"))
        else:
            mesh = make_abstract_mesh((cand.stages,), ("stage",))
    return build_plan(cfg, mesh, mode=cand.mode, exact=cand.exact)


def engine_kwargs(score: Score) -> Dict[str, Any]:
    """ContinuousBatchingEngine kwargs the chosen candidate implies."""
    cand = score.cand
    kw: Dict[str, Any] = {"paged": cand.paged}
    if cand.paged:
        kw.update(page_size=cand.page_size, kv_dtype=cand.kv_dtype)
    if cand.disagg_prefill:
        kw["disagg"] = (cand.disagg_prefill, cand.disagg_decode)
    return kw


# ---------------------------------------------------------------------------
# snapshots (benchmarks/plans/<family>.json)
# ---------------------------------------------------------------------------


def to_snapshot(cfg, result: SearchResult) -> Dict[str, Any]:
    ch = result.chosen
    snap: Dict[str, Any] = {
        "arch": cfg.name,
        "cost_model_version": COST_MODEL_VERSION,
        "profile": result.profile.to_dict(),
        "n_candidates": len(result.scores),
        "n_feasible": result.n_feasible,
        "frontier": [s.key for s in result.frontier],
        "chosen": None,
    }
    if ch is not None:
        snap["chosen"] = {
            "key": ch.key, **asdict(ch.cand),
            "replicas": ch.replicas,
            "predicted": {"pred_tok_s": round(ch.tok_s, 3),
                          "pred_ttft_ms": round(ch.ttft_ms, 4),
                          "pred_hbm_frac": round(ch.hbm_frac, 4)},
        }
    return snap


def diff_snapshots(old: Dict[str, Any],
                   new: Dict[str, Any]) -> Tuple[List[str], List[str]]:
    """(hard drift, informational drift).  Structural changes — chosen
    candidate, frontier membership, profile, cost-model version — fail
    the gate; predicted-number deltas beyond 2 % are reported but
    informational (jax version skew can shift traced byte counts without
    changing the ranking)."""
    hard: List[str] = []
    info: List[str] = []
    for k in ("arch", "cost_model_version", "profile",
              "n_candidates", "n_feasible", "frontier"):
        if old.get(k) != new.get(k):
            hard.append(f"{k}: {old.get(k)!r} -> {new.get(k)!r}")
    oc, nc = old.get("chosen"), new.get("chosen")
    if (oc is None) != (nc is None):
        hard.append(f"chosen: {oc and oc.get('key')!r} -> "
                    f"{nc and nc.get('key')!r}")
    elif oc is not None and nc is not None:
        for k in sorted(set(oc) | set(nc)):
            if k == "predicted":
                continue
            if oc.get(k) != nc.get(k):
                hard.append(f"chosen.{k}: {oc.get(k)!r} -> {nc.get(k)!r}")
        op, np_ = oc.get("predicted") or {}, nc.get("predicted") or {}
        for k in sorted(set(op) | set(np_)):
            a, b = op.get(k), np_.get(k)
            if a is None or b is None or a == 0:
                if a != b:
                    info.append(f"predicted.{k}: {a!r} -> {b!r}")
            elif abs(b - a) / abs(a) > 0.02:
                info.append(f"predicted.{k}: {a} -> {b} "
                            f"({(b - a) / a:+.1%})")
    return hard, info


# ---------------------------------------------------------------------------
# predicted-vs-measured (the perf.yml accuracy band)
# ---------------------------------------------------------------------------

# Acceptable predicted/measured tok/s ratio for the CI band.  The
# calibration below pins the device's decode-step, dispatch and prefill
# costs on the same box in the same run, so machine speed cancels and the
# band only absorbs scheduler/occupancy modelling error plus CI noise.
PREDICTION_BAND = (0.5, 2.0)

# Occupancy constant: fraction of scheduler lanes carrying live tokens
# over a serve bench (admission ramps + tail drain keep it under 1).
STEADY_OCCUPANCY = 0.8
# Fraction of the dispatched horizon that emits surviving tokens.
HORIZON_UTILIZATION = 0.75


@dataclass(frozen=True)
class DeviceCalibration:
    """Two-point decode fit: time a fused n-step dispatch at n_lo and
    n_hi, then

        t_step     = (t_hi - t_lo) / (n_hi - n_lo)   marginal step cost
        t_dispatch = t_lo - n_lo * t_step            fixed launch cost

    — the measured analogue of the paper's Table 1 (T and I measured on
    the proof-of-concept, then projected).  `t_prefill_s` is a third
    probe: one batch-1 bucketed prefill dispatch, the unit the engine's
    admission path pays per request."""
    t_step_s: float
    t_dispatch_s: float
    t_prefill_s: float = 0.0

    @classmethod
    def from_two_point(cls, t_lo: float, n_lo: int, t_hi: float,
                       n_hi: int,
                       t_prefill: float = 0.0) -> "DeviceCalibration":
        step = max((t_hi - t_lo) / max(n_hi - n_lo, 1), 1e-9)
        return cls(t_step_s=step,
                   t_dispatch_s=max(t_lo - n_lo * step, 0.0),
                   t_prefill_s=t_prefill)


def predict_engine_tok_s(calib: DeviceCalibration, *, n_requests: int,
                         total_tokens: int, prompt_tokens: int,
                         max_batch: int, horizon: int) -> float:
    """Predicted end-to-end tok/s for a continuous-batching bench run
    from the calibrated step/dispatch costs and the stream's declared
    shape.  Kept deliberately simple — the point of the CI band is to
    catch the cost model drifting from the device, not to model the
    scheduler exactly."""
    lanes = max(max_batch * STEADY_OCCUPANCY, 1.0)
    steps = total_tokens / lanes
    h_eff = max(horizon * HORIZON_UTILIZATION, 1.0)
    decode_s = steps * calib.t_step_s + (steps / h_eff) * calib.t_dispatch_s
    # prefill: the engine admits one prompt per dispatch (batch-1
    # bucketed prefill) — priced by the calibration's prefill probe when
    # present, else approximated from the decode-step cost
    if calib.t_prefill_s > 0:
        per_req = calib.t_prefill_s
    else:
        per_req = (calib.t_dispatch_s
                   + calib.t_step_s * (prompt_tokens / max(n_requests, 1))
                   / max(max_batch, 1))
    return total_tokens / max(decode_s + n_requests * per_req, 1e-9)


def prediction_ratio_ok(ratio: float,
                        band: Tuple[float, float] = PREDICTION_BAND) -> bool:
    lo, hi = band
    return lo <= ratio <= hi
