"""Pipeline parallelism over clusters (paper §7.2 + §8 Eq. 1 runtime).

The paper deploys I-BERT as one encoder per 6-FPGA cluster, clusters chained
serially in dataflow; the full-model latency follows Eq. 1
``T + (L-1)(X+d)``.  TPU mapping: stage = cluster, the stage axis is a mesh
axis (`pod` for the multi-pod plan, or a dedicated `stage` axis), microbatches
stream GPipe-style and move between stages with collective_permute — the
SPMD form of the paper's gateway-to-gateway inter-cluster messages.

Implemented inside shard_map: stage s holds its slice of the stacked stage
parameters; step t processes microbatch (t - s) and ppermutes activations
forward.  Total steps = n_micro + n_stages - 1, i.e. Eq. 1 with X = T_stage.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 top-level API with check_vma
    shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except AttributeError:  # older jax: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map
    _SHARD_MAP_KW = {"check_rep": False}


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions."""
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **_SHARD_MAP_KW)


def pipeline_steps(n_micro: int, n_stages: int) -> int:
    return n_micro + n_stages - 1


def gpipe_forward_perm(n_stages: int):
    """The forward collective_permute pairs of the GPipe schedule — stage s
    hands its activation to s+1 (the last stage's wrap-around carries
    garbage that no active stage ever reads).  Shared by `pipelined_apply`
    (training/prefill microbatches) and the serving executor's pipelined
    decode program (decode micro-steps), so the schedule can't drift
    between the two."""
    return [(i, (i + 1) % n_stages) for i in range(n_stages)]


def pipelined_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                    mesh: Mesh, axis: str,
                    stage_params: Any, x_micro: jax.Array) -> jax.Array:
    """Run x through n_stages stage_fns, GPipe-schedule over `axis`.

    stage_params: pytree with leading dim n_stages (sharded over `axis`).
    x_micro: (n_micro, mb, ...) microbatched input (replicated over `axis`).
    Returns (n_micro, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    steps = pipeline_steps(n_micro, n_stages)
    fwd = gpipe_forward_perm(n_stages)

    def body(params, xs):
        # inside shard_map: params leaves have leading dim 1 (this stage)
        params = jax.tree.map(lambda p: p[0], params)
        sidx = lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])  # activation in flight
        out = jnp.zeros_like(xs)

        def step(t, carry):
            buf, out = carry
            # stage 0 ingests microbatch t; others take the permuted buffer
            x_in = jnp.where(sidx == 0,
                             xs[jnp.minimum(t, n_micro - 1)], buf)
            active = (sidx <= t) & (t - sidx < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage writes its result to output slot t-(n_stages-1)
            oslot = t - (n_stages - 1)
            write = (sidx == n_stages - 1) & (oslot >= 0)
            out = lax.cond(
                write,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(oslot, 0), 0),
                lambda o: o, out)
            buf = lax.ppermute(y, axis, fwd)
            return buf, out

        _, out = lax.fori_loop(0, steps, step, (buf, out))
        # results live on the last stage; share them with every stage
        out = lax.psum(jnp.where(sidx == n_stages - 1, out, 0.0), axis)
        return out

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        **_SHARD_MAP_KW,
    )(stage_params, x_micro)
