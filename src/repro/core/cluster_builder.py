"""Cluster Builder (paper §6): model description -> deployable parallel plan.

The paper's Cluster Builder takes a trained model + JSON cluster/layer
descriptions and emits per-kernel HLS artifacts wired into Galapagos
clusters.  The TPU analogue emits, from a ModelConfig + mesh:

  1. a ClusterTopology — the paper's kernel graph (one cluster per layer,
     gateway kernel 0, per-head compute kernels, inserted GMI kernels).  For
     ibert-base this reproduces Fig. 14's 39-kernel encoder cluster exactly.
     It drives the routing-table/deployment benchmarks and documents how the
     model WOULD be laid out on a kernel-granular spatial fabric.
  2. a ShardingPlan — PartitionSpecs for every parameter / batch / cache
     leaf.  This is what the XLA SPMD partitioner consumes; it plays the
     role Vivado bitstream generation plays in the paper (DESIGN.md §2).

Sharding rules are divisibility-driven: tensor-parallel dims go to `model`,
FSDP dims to ("pod","data") when divisible, with graceful fallback to
replication — so every assigned arch (9-head smollm, 151655-vocab internvl2,
...) gets a coherent plan on the same production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cluster import Cluster, ClusterTopology

# ---------------------------------------------------------------------------
# Part 1: kernel-graph topology (paper-faithful bookkeeping)
# ---------------------------------------------------------------------------


def _attn_layer_kernels(c: Cluster, cfg: ModelConfig,
                        with_dense_ffn: bool) -> None:
    """Mirror of Fig. 14: one encoder's kernels (per-head spatial split)."""
    for name in ("linear_q_quant", "linear_k_quant", "linear_v_quant"):
        c.add("compute", name)
    for h in range(cfg.n_heads):
        c.add("compute", "dotprod_softmax", head=h)
    for h in range(cfg.n_heads):
        c.add("compute", "softmax_matmul_quant", head=h)
    c.add("compute", "linear_o_quant")
    c.add("compute", "layernorm")
    if with_dense_ffn:
        c.add("compute", "linear_ff1_gelu")
        c.add("compute", "linear_ff2_quant")
        c.add("compute", "layernorm")
    c.add("gmi", "scatter")  # split Q/K/V head blocks across head kernels
    c.add("gmi", "scatter")
    c.add("gmi", "scatter")
    c.add("gmi", "gather")  # gather head outputs
    c.add("gmi", "broadcast")  # residual fan-out


def _moe_layer_kernels(c: Cluster, cfg: ModelConfig) -> None:
    c.add("compute", "router")
    c.add("gmi", "scatter")  # dispatch (the MoE all-to-all)
    for e in range(cfg.n_experts):
        c.add("compute", "expert_ffn", expert=e)
    for s in range(cfg.n_shared_experts):
        c.add("compute", "shared_expert_ffn", expert=s)
    c.add("gmi", "gather")  # combine
    c.add("compute", "layernorm")


def _recurrent_layer_kernels(c: Cluster, kind: str,
                             with_dense_ffn: bool = False) -> None:
    c.add("compute", f"{kind}_in_proj")
    c.add("compute", f"{kind}_cell")
    c.add("compute", f"{kind}_out_proj")
    c.add("compute", "layernorm")
    if with_dense_ffn:
        c.add("compute", "linear_ff1_gelu")
        c.add("compute", "linear_ff2_quant")
        c.add("compute", "layernorm")


def build_topology(cfg: ModelConfig) -> ClusterTopology:
    """One cluster per layer (the paper maps one encoder per cluster)."""
    topo = ClusterTopology()
    prev_gateway = None
    for layer in range(cfg.n_layers):
        c = topo.new_cluster()
        kind = cfg.block_kind(layer)
        is_moe = cfg.is_moe_layer(layer)
        if kind == "attn":
            _attn_layer_kernels(c, cfg, with_dense_ffn=not is_moe and
                                cfg.family != "ssm" and cfg.d_ff > 0)
        else:
            _recurrent_layer_kernels(
                c, kind, with_dense_ffn=cfg.family != "ssm" and cfg.d_ff > 0)
        if is_moe:
            _moe_layer_kernels(c, cfg)
        if prev_gateway is not None:
            topo.connect(prev_gateway, c.gateway)  # serial encoder chain
        prev_gateway = c.gateway
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Part 2: sharding plan
# ---------------------------------------------------------------------------


@dataclass
class MeshAxes:
    dp: Tuple[str, ...]  # data-parallel axes, e.g. ("pod","data")
    tp: str = "model"
    stage: Optional[str] = None  # pipeline axis (mode="serve_pipeline")

    @property
    def all(self) -> Tuple[str, ...]:
        return self.dp + (self.tp,)


@dataclass
class ClusterPlan:
    cfg: ModelConfig
    axes: MeshAxes
    mesh: Mesh
    topology: ClusterTopology
    param_specs: Any = None
    cache_specs: Any = None
    data_spec: Any = None
    mode: str = "train"
    fsdp: bool = True
    # exact=True (default): bit-identical serving — gather-form TP and the
    # drained pipeline schedule.  exact=False: throughput mode — psum-form
    # (Megatron) TP for the reduction projections and the request-skewed
    # pipeline schedule; streams are gated by a token-match band instead of
    # equality (docs/serving.md §exactness contract).
    exact: bool = True
    notes: List[str] = field(default_factory=list)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- late-binding specs (the serving engine builds its slot-table cache
    #    after the plan exists, so specs must be derivable post-hoc) --------

    def specs_for_params(self, params_shape: Any) -> Any:
        if self.mode == "serve_pipeline":
            n = _axsize(self.mesh, self.axes.stage)
            return _tree_specs(
                params_shape,
                lambda p, s: _stage_spec(p, s, self.axes.stage, n))
        r = Rules(self.mesh, self.axes, fsdp=self.fsdp)
        return _tree_specs(
            params_shape, lambda p, s: _param_spec(p, s, r, self.cfg.family,
                                                   mode=self.mode,
                                                   exact=self.exact))

    def specs_for_caches(self, caches_shape: Any, batch: int = 0,
                         slot_table: bool = False,
                         paged: bool = False) -> Any:
        """slot_table=True: the continuous-batching engine's persistent
        cache, admitted into at traced slot indices — the slot (batch) dim
        must stay unsharded or every insert crosses data shards.

        paged=True: the cache tree is a *paged arena*
        (`Model.init_paged_cache`) — per-layer `k`/`v` arenas
        (P, ps, KVH, hd) and `k_scale`/`v_scale` planes (P, ps, KVH) shard
        the kv-head dim over `model` (decode reads and the per-step scatter
        writes stay shard-local: the scatter addresses pages/offsets, never
        the head dim), while `kpos`, the per-lane page tables `pt` and the
        position counters `pos` replicate — the page table is the *shared*
        routing metadata every model shard walks identically, the TPU
        analogue of the paper's gateway routing tables."""
        if self.mode == "serve_pipeline":
            n = _axsize(self.mesh, self.axes.stage)
            return _tree_specs(
                caches_shape,
                lambda p, s: _stage_spec(p, s, self.axes.stage, n))
        r = Rules(self.mesh, self.axes, fsdp=self.fsdp)
        if paged:
            return _tree_specs(
                caches_shape, lambda p, s: _paged_cache_spec(p, s, r))
        return _tree_specs(
            caches_shape,
            lambda p, s: _cache_spec(p, s, r, batch, mode=self.mode,
                                     slot_table=slot_table))


def _axsize(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class Rules:
    """Divisibility-driven spec assignment for one tensor."""

    def __init__(self, mesh: Mesh, axes: MeshAxes, fsdp: bool = True):
        self.mesh, self.axes = mesh, axes
        self.tp_n = _axsize(mesh, axes.tp)
        self.dp_opts: List[Tuple[str, ...]] = []
        if fsdp:
            for i in range(len(axes.dp)):
                self.dp_opts.append(tuple(axes.dp[i:]))  # ("pod","data"),..

    def spec(self, shape: Sequence[int], tp_dim: Optional[int],
             fsdp_dim: Optional[int], offset: int = 0) -> P:
        """tp_dim/fsdp_dim are indices into `shape` (post-offset) or None."""
        parts: List[Any] = [None] * (len(shape) + offset)
        if tp_dim is not None and shape[tp_dim] % self.tp_n == 0:
            parts[offset + tp_dim] = self.axes.tp
        else:
            tp_dim = None
        if fsdp_dim is not None and fsdp_dim != tp_dim:
            for cand in self.dp_opts:
                n = 1
                for a in cand:
                    n *= self.mesh.shape[a]
                if shape[fsdp_dim] % n == 0:
                    parts[offset + fsdp_dim] = cand if len(cand) > 1 else cand[0]
                    break
        return P(*parts)


def _param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
                r: Rules, family: str = "dense", mode: str = "train",
                exact: bool = True) -> P:
    """Rule table keyed on parameter names (see models/).

    mode="serve": the *reduction* projections (attention `wo`, MLP/MoE
    down-projections) replicate instead of sharding their contraction dim.
    This is the paper's Fig. 14 mapping verbatim: per-head kernels compute
    in parallel, a GMI `gather` collects the head outputs, and `linear_o`
    runs on the gathered activation — so the only cross-device reductions
    left are exact (gathers, not partial-sum psums) and a plan-sharded
    engine's token streams stay BIT-IDENTICAL to single-device serving
    (tests/test_sharded_serving.py).  Serving decode activations are tiny
    (one row per lane), so the gather costs what the psum would have.
    """
    name = path[-1]
    # int8-serving leaves: "q" shards like its parent weight, "s" replicates
    if name == "s" and len(path) > 1 and path[-2] in (
            "wq", "wk", "wv", "wo", "wi", "wg", "shared_wi", "shared_wg",
            "shared_wo"):
        return P(*([None] * len(shape)))
    if name == "q" and len(path) > 1:
        name = path[-2]
    in_scan = "scan" in path  # leading stacked layer dim -> never sharded
    off = 1 if in_scan else 0
    s = shape[off:]
    nd = len(s)

    def mk(tp, fsdp):
        return r.spec(s, tp, fsdp, offset=off)

    # serve mode, TP-only plans (fsdp off): reduction projections
    # replicate (gather-form TP — the Fig. 14 gather-then-linear_o
    # mapping; exactness note in docstring).  When the plan KEPT fsdp for
    # capacity (the 400B 50GB/chip case), replicating the largest weight
    # class would OOM exactly where fsdp was retained to prevent it, so
    # those plans fall through to the normal TP+FSDP rules — correctness
    # is unchanged, only the cross-device-count bit-identity contract is
    # scoped to TP-only serve plans (docs/serving.md).
    #
    # exact=False serve plans SKIP this rule: the reduction projections
    # fall through to their normal column-sharded specs (contraction dim
    # over `model` — Megatron psum-form TP).  The matching activation
    # constraint is `hint(x, "psum")` in attention/mlp, which keeps the
    # dot partial per shard and lets XLA insert one all-reduce — the
    # paper's cross-FPGA float accumulation, accepted in exchange for the
    # tok/s ceiling (docs/serving.md §exactness contract).
    if mode == "serve" and exact and not r.dp_opts and name in (
            "wo", "shared_wo", "glu_wo", "down", "w_out"):
        return P(*([None] * len(shape)))
    # embeddings / head
    if name in ("tok", "head"):
        if name == "tok" and s[0] % r.tp_n == 0:
            return mk(0, 1)  # vocab over model, d over fsdp
        return mk(1, 0)  # fall back: d over model
    if name == "pos":
        return mk(None, None)
    # sLSTM cell does not tensor-parallelize (per-step state math would
    # reshard every scan iteration — DESIGN.md §5): its gate projection and
    # recurrent matrices stay with the (batch-sharded) state
    if name == "w_in":
        return mk(None, 0)
    if name == "r" and nd == 4:
        return P(*([None] * len(shape)))
    if name == "w_if":  # mLSTM scalar gates: tiny, replicated
        return P(*([None] * len(shape)))
    # attention
    if name in ("wq", "wk", "wv") and nd == 2:
        return mk(1, 0)
    if name == "wo" and nd == 2:
        return mk(0, 1)
    if name in ("wq", "wk", "wv") and nd == 3:  # mlstm per-head (nh, ih, dk)
        return mk(1, None)
    # mlp / moe
    if name in ("wi", "wg"):
        return mk(0 if nd == 3 else 1, 1 if nd == 3 else 0)  # moe: E over model
    if name == "wo" and nd == 3:
        return mk(0, 1)
    if name in ("shared_wi", "shared_wg", "glu_wi", "up_z", "up_g",
                "w_gate_in", "w_x_in"):
        return mk(1, 0)
    if name in ("shared_wo", "glu_wo", "down", "w_out"):
        return mk(0, 1)
    if name in ("w_rgate", "w_igate"):
        # contraction dim on `model` to match the W-sharded conv output —
        # otherwise XLA all-gathers the (B,S,W) activation (§Perf A2)
        return mk(0, None)
    if name == "conv" and nd == 2:
        return mk(1, None)
    if name in ("lam",) and nd == 1:
        return mk(0, None)
    if name == "router":
        return mk(None, 0)  # small but scan-stacked: FSDP the d dim
    # norms, biases, gains
    return P(*([None] * len(shape)))


def _cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...], r: Rules,
                batch: int, mode: str = "train",
                slot_table: bool = False) -> P:
    name = path[-1]
    in_scan = "scan" in path
    off = 1 if in_scan else 0
    s = shape[off:]
    dp = None
    if not slot_table:  # slot tables: inserts at traced slots stay local
        for cand in r.dp_opts:
            n = 1
            for a in cand:
                n *= r.mesh.shape[a]
            if s and s[0] % n == 0:
                dp = cand if len(cand) > 1 else cand[0]
                break
    parts: List[Any] = [None] * len(shape)
    if s:
        parts[off] = dp  # batch dim
    if name in ("k", "v") and len(s) == 4 and mode == "serve":
        # slot-table layout (continuous batching): the cache is persistent
        # for the whole serving session, so the one-off insert reshard at
        # admission amortizes over the slot's lifetime.  TP the kv-head dim
        # only — decode reads split across `model`, while per-slot inserts
        # and per-step KV writes (batch + seq addressed) stay shard-local.
        if s[2] % r.tp_n == 0:
            parts[off + 2] = r.axes.tp
    elif name in ("k", "v") and len(s) == 4:
        # prefer kv-head TP; else shard head_dim (decode writes at dynamic
        # seq slots stay shard-local; a seq-sharded cache makes SPMD
        # replicate the buffer around every cache write — §Perf 0.7).
        # Small (windowed / short) caches skip TP entirely: the write-side
        # reshard costs more than replication saves (§Perf A5).
        import numpy as _np
        dp_n = 1
        for cand in r.dp_opts[:1]:
            for a in cand:
                dp_n *= r.mesh.shape[a]
        per_dev_dp_only = int(_np.prod(s)) * 2 / max(dp_n, 1)
        if per_dev_dp_only > 5e8:
            if s[2] % r.tp_n == 0:
                parts[off + 2] = r.axes.tp
            elif s[3] % r.tp_n == 0:
                parts[off + 3] = r.axes.tp
            elif s[1] % r.tp_n == 0:
                parts[off + 1] = r.axes.tp
    elif name in ("h", "C") and len(s) >= 2:
        if s[-1] % r.tp_n == 0:
            parts[off + len(s) - 1] = r.axes.tp
    elif name in ("c", "n", "m") and len(s) >= 2 and s[-1] % r.tp_n == 0:
        parts[off + len(s) - 1] = r.axes.tp
    elif name == "conv" and len(s) == 3 and s[-1] % r.tp_n == 0:
        parts[off + 2] = r.axes.tp
    return P(*parts)


def _paged_cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
                      r: Rules) -> P:
    """Leaf rules for a paged KV arena (serve mode).

    Arena leaves have a *page* axis where dense slot caches have batch:
    `k`/`v` (P, ps, KVH, hd) and the int8 path's `k_scale`/`v_scale`
    (P, ps, KVH) put the kv-head dim on `model` when divisible — the reads
    (paged_flash_decode under shard_map) and the decode scatter (addressed
    by page/offset) then never cross shards.  `kpos` (P, ps), the per-lane
    page tables `pt` (B, MAXP) and position counters `pos` (B,) replicate:
    they are the routing metadata every shard must walk identically.
    """
    name = path[-1]
    in_scan = "scan" in path
    off = 1 if in_scan else 0
    s = shape[off:]
    parts: List[Any] = [None] * len(shape)
    if name in ("k", "v") and len(s) == 4 and s[2] % r.tp_n == 0:
        parts[off + 2] = r.axes.tp
    elif name in ("k_scale", "v_scale") and len(s) == 3 \
            and s[2] % r.tp_n == 0:
        parts[off + 2] = r.axes.tp
    return P(*parts)


def _stage_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
                stage: str, stage_n: int) -> P:
    """mode="serve_pipeline": scan-stacked leaves (leading dim = repeated
    periods) shard that dim over the `stage` axis — stage s holds its
    contiguous slice of the layer stack, the paper's one-encoder-per-
    cluster placement — and everything else (embeddings, norms, tail
    blocks, per-lane decode state) replicates so the token feedback loop
    runs identically on every stage."""
    if "scan" in path and len(shape) >= 1 and shape[0] % stage_n == 0:
        return P(*((stage,) + (None,) * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def _tree_specs(tree, fn) -> Any:
    """Map fn(path, aval) over a pytree of ShapeDtypeStructs/arrays."""

    def go(sub, path):
        if isinstance(sub, dict):
            return {k: go(v, path + (k,)) for k, v in sub.items()}
        return fn(path, tuple(sub.shape))

    return go(tree, ())


def build_plan(cfg: ModelConfig, mesh: Mesh,
               params_shape: Any = None,
               caches_shape: Any = None,
               batch: int = 0,
               mode: str = "train",
               exact: bool = True) -> ClusterPlan:
    """The Cluster Builder entry point used by launch/ and tests.

    mode="serve": weights are sharded over `model` only (no FSDP) — there
    are no gradients, and FSDP'd contraction dims turn every projection
    into a cross-data all-reduce (§Perf iteration A1: -46%% collective
    bytes on recurrentgemma prefill).  FSDP is kept when TP-only weights
    would not fit HBM (the 400B arch: 50GB/chip TP-only).  Reduction
    projections replicate (gather-form TP, `_param_spec`), so a serve
    plan's outputs are bit-identical to single-device serving.

    mode="serve_pipeline": the mesh must carry a `stage` axis; the
    scan-stacked layer dim shards over it (stage s = its slice of the
    layer stack, the paper's encoder-per-cluster placement) and everything
    else replicates — the serving executor streams decode micro-steps
    through the stages with collective_permute (serving/executor.py).

    exact=False (serve modes only): throughput plans.  serve switches the
    reduction projections to psum-form TP; serve_pipeline switches the
    executor to the request-skewed schedule with stage-local paged
    arenas.  Token streams then satisfy a match-rate band, not equality.
    """
    if mode == "serve_pipeline":
        if "stage" not in mesh.shape:
            raise ValueError(
                "mode='serve_pipeline' needs a mesh with a 'stage' axis "
                "(e.g. make_mesh((n,), ('stage',)))")
        axes = MeshAxes(dp=(), tp="model" if "model" in mesh.shape
                        else "stage", stage="stage")
        plan = ClusterPlan(cfg=cfg, axes=axes, mesh=mesh,
                           topology=build_topology(cfg), mode=mode,
                           fsdp=False, exact=exact)
        if params_shape is not None:
            plan.param_specs = plan.specs_for_params(params_shape)
        if caches_shape is not None:
            plan.cache_specs = plan.specs_for_caches(caches_shape, batch)
        plan.data_spec = lambda ndim, b: P(*((None,) * ndim))
        return plan
    axes = MeshAxes(
        dp=tuple(a for a in ("pod", "data") if a in mesh.shape), tp="model"
    )
    fsdp = True
    if mode == "serve":
        per_chip = cfg.param_count() * 2 / _axsize(mesh, axes.tp)
        fsdp = per_chip > 8e9  # keep FSDP only when capacity demands it
    plan = ClusterPlan(cfg=cfg, axes=axes, mesh=mesh,
                       topology=build_topology(cfg), mode=mode, fsdp=fsdp,
                       exact=exact)
    if params_shape is not None:
        plan.param_specs = plan.specs_for_params(params_shape)
    if caches_shape is not None:
        plan.cache_specs = plan.specs_for_caches(caches_shape, batch)
    # batch specs
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def tok_spec(b):
        ok = batch and b % _axsize(mesh, axes.dp) == 0
        return dp if ok else None

    plan.data_spec = lambda ndim, b: P(*((tok_spec(b),) + (None,) * (ndim - 1)))
    return plan
