"""Clusters-of-clusters topology and 2-level kernel addressing (paper §4).

The paper scales Galapagos past its 256-kernel limit by building clusters of
clusters: kernel addresses become (cluster_id, local_id), all inter-cluster
messages go through each cluster's Gateway kernel (local_id 0), and each FPGA
stores 2N-1 routes instead of N^2.  This module keeps that bookkeeping: the
Cluster Builder assigns kernel IDs out of it, tests assert the paper's
routing-table arithmetic, and the launcher maps clusters onto mesh axes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

MAX_KERNELS_PER_CLUSTER = 256  # Galapagos hard limit (paper §4)
MAX_CLUSTERS = 256
GATEWAY_LOCAL_ID = 0

KernelId = Tuple[int, int]  # (cluster_id, local_id)


@dataclass
class Kernel:
    cluster_id: int
    local_id: int
    kind: str  # "gateway" | "compute" | "gmi" | "virtual"
    op: str = ""  # e.g. "linear_quant", "softmax", "broadcast", "scatter"
    meta: Dict = field(default_factory=dict)

    @property
    def kid(self) -> KernelId:
        return (self.cluster_id, self.local_id)

    @property
    def global_id(self) -> int:
        return self.cluster_id * MAX_KERNELS_PER_CLUSTER + self.local_id


@dataclass
class Cluster:
    cluster_id: int
    kernels: List[Kernel] = field(default_factory=list)

    def add(self, kind: str, op: str = "", **meta) -> Kernel:
        local_id = len(self.kernels)
        if local_id >= MAX_KERNELS_PER_CLUSTER:
            raise ValueError(
                f"cluster {self.cluster_id} exceeds the "
                f"{MAX_KERNELS_PER_CLUSTER}-kernel Galapagos limit; "
                f"split across more clusters (paper §4)")
        k = Kernel(self.cluster_id, local_id, kind, op, dict(meta))
        self.kernels.append(k)
        return k

    @property
    def gateway(self) -> Kernel:
        return self.kernels[GATEWAY_LOCAL_ID]


@dataclass
class ClusterTopology:
    clusters: List[Cluster] = field(default_factory=list)
    edges: List[Tuple[KernelId, KernelId]] = field(default_factory=list)

    def new_cluster(self) -> Cluster:
        if len(self.clusters) >= MAX_CLUSTERS:
            raise ValueError(f"exceeds {MAX_CLUSTERS}-cluster limit (§4)")
        c = Cluster(len(self.clusters))
        # kernel 0 of every cluster is the Gateway (paper's restriction)
        c.add("gateway", "gateway")
        self.clusters.append(c)
        return c

    def connect(self, src: Kernel, dst: Kernel) -> None:
        """Intra-cluster edges are direct; inter-cluster edges MUST route
        via the destination cluster's gateway (paper §4)."""
        if src.cluster_id != dst.cluster_id and dst.kind != "gateway":
            gw = self.clusters[dst.cluster_id].gateway
            self.edges.append((src.kid, gw.kid))
            self.edges.append((gw.kid, dst.kid))
        else:
            self.edges.append((src.kid, dst.kid))

    # -- the paper's routing-table arithmetic --------------------------------

    def routing_entries_per_device(self) -> int:
        """2N-1 with gateways (N = clusters): N-1 gateway routes + N kernels
        in own cluster (paper §4)."""
        n = len(self.clusters)
        k = max((len(c.kernels) for c in self.clusters), default=0)
        return k + (n - 1)

    def routing_entries_flat(self) -> int:
        """N^2-style entries if any kernel could address any other directly."""
        return sum(len(c.kernels) for c in self.clusters)

    @property
    def total_kernels(self) -> int:
        return sum(len(c.kernels) for c in self.clusters)

    def validate(self) -> None:
        assert len(self.clusters) <= MAX_CLUSTERS
        for c in self.clusters:
            assert len(c.kernels) <= MAX_KERNELS_PER_CLUSTER
            assert c.kernels[GATEWAY_LOCAL_ID].kind == "gateway"
            ids = [k.local_id for k in c.kernels]
            assert ids == list(range(len(ids))), "kernel IDs must be contiguous"
        for (sc, sl), (dc, dl) in self.edges:
            if sc != dc:
                assert dl == GATEWAY_LOCAL_ID or sl == GATEWAY_LOCAL_ID, (
                    "inter-cluster edge bypasses the gateway")


def max_addressable_kernels() -> int:
    return MAX_CLUSTERS * MAX_KERNELS_PER_CLUSTER  # 65536 (paper §4)
