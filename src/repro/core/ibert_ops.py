"""Integer-only I-BERT nonlinearities (Kim et al. 2021), TPU-adapted.

The paper (§2.4, §7) implements Quant/Softmax/LayerNorm/GELU "the same way as
the software version of I-BERT": second-order polynomial approximations of
exp/erf and a Newton integer square root, so that the whole encoder runs in
INT8/INT32 with float touch-points only at scale factors.

TPU adaptation (DESIGN.md §2): the published I-BERT code rides on torch int
tensors with effectively 64-bit intermediate products.  Pallas TPU integer
lanes are 32-bit, so nonlinearity *inputs* are requantized to ACT_BITS=12
bits (|q| <= 2047).  With 12-bit inputs every intermediate below provably
fits int32 (bounds in comments).  This is a hardware-codesign decision of the
same kind the paper makes when sizing PEs/BRAM.

Every function here is pure jnp and integer-valued (scales are f32 metadata).
kernels/ref.py re-exports these as the oracles for the Pallas kernels.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, _round_half_away

ACT_BITS = 12  # nonlinearity-input precision (TPU int32-safety, see module doc)
ACT_QMAX = 2 ** (ACT_BITS - 1) - 1  # 2047
SOFTMAX_OUT_BITS = 14  # probabilities emitted with scale 2**-14
LN_NORM_SHIFT = 11  # normalized value scale 2**-11
MIN_RANGE = 0.5  # dynamic-range floor before nonlinearities: keeps S >= ~1.7e-4
#                 so every polynomial constant below provably fits int32


def _to_i32(x: jax.Array) -> jax.Array:
    """Saturating float->int32 (guards jnp.floor(huge) -> UB casts)."""
    return jnp.clip(x, -2.147e9, 2.147e9).astype(jnp.int32)

# I-BERT polynomial constants
_EXP_A, _EXP_B, _EXP_C = 0.35815147, 1.353, 0.344
_ERF_A, _ERF_B, _ERF_C = -0.2888, -1.769, 1.0
_LN2 = math.log(2.0)
_EXP_CLAMP = -30.0  # exp(-30) ~ 9e-14: clamp keeps z*q_ln2 within int32


def requantize_to_bits(q: jax.Array, scale: jax.Array, bits: int = ACT_BITS,
                       axis=None, min_range: float = MIN_RANGE) -> QTensor:
    """Dynamic-range integer->integer requant (the paper's Quant module).

    amax is taken over the integer values (integer max + one float multiply),
    matching how the FPGA Quant block tracks ranges.  `min_range` floors the
    represented real range so downstream polynomial constants stay in int32.
    """
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(q))
    else:
        amax = jnp.max(jnp.abs(q), axis=axis, keepdims=True)
    range_f = jnp.maximum(amax.astype(jnp.float32) * scale, min_range)
    s_out = range_f / qmax
    ratio = scale / s_out
    out = _round_half_away(q.astype(jnp.float32) * ratio)
    return QTensor(jnp.clip(out, -qmax, qmax).astype(jnp.int32), s_out)


# ---------------------------------------------------------------------------
# i-exp  (I-BERT Alg. 2): exp(qS) for q <= 0
# ---------------------------------------------------------------------------


def i_exp(q: jax.Array, scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """q: int32 <= 0.  Returns (q_exp >= 0 int32, S_exp f32 scalar).

    Bounds (ACT_BITS=12, S >= ~1e-3): q_ln2 <= ~700, z <= 44, q_b <= ~1.4e3,
    (p+q_b)^2 <= ~4.4e6, q_c <= ~1e6 -> all << 2^31.
    """
    scale = jnp.asarray(scale, jnp.float32)
    q_clamp = jnp.floor(_EXP_CLAMP / scale).astype(jnp.int32)
    q = jnp.maximum(q, q_clamp)

    q_ln2 = jnp.maximum(_to_i32(jnp.floor(_LN2 / scale)), 1)
    z = (-q) // q_ln2  # >= 0
    p = q + z * q_ln2  # in (-q_ln2, 0]

    q_b = _to_i32(jnp.floor(_EXP_B / scale))
    q_c = _to_i32(jnp.floor(_EXP_C / (_EXP_A * scale * scale)))
    t = p + q_b
    q_l = t * t + q_c  # scale a*S^2
    q_out = q_l >> z.astype(jnp.int32)  # /2^z (q_l >= 0)
    s_out = _EXP_A * scale * scale
    return q_out.astype(jnp.int32), s_out


# ---------------------------------------------------------------------------
# i-sqrt  (I-BERT Alg. 4): integer Newton sqrt with early-stop semantics
# ---------------------------------------------------------------------------

_ISQRT_ITERS = 20


def i_sqrt(n: jax.Array) -> jax.Array:
    """Elementwise integer sqrt of non-negative int32 (floor-ish, I-BERT Alg.4)."""
    n = n.astype(jnp.int32)
    bits = jnp.ceil(jnp.log2(jnp.maximum(n, 1).astype(jnp.float32) + 1.0))
    x0 = jnp.exp2(jnp.ceil(bits / 2.0)).astype(jnp.int32)
    x0 = jnp.maximum(x0, 1)

    def body(_, carry):
        x, done = carry
        nx = (x + n // jnp.maximum(x, 1)) >> 1
        newdone = done | (nx >= x)
        return jnp.where(newdone, x, nx), newdone

    x, _ = jax.lax.fori_loop(
        0, _ISQRT_ITERS, body, (x0, jnp.zeros_like(n, dtype=bool))
    )
    return jnp.where(n == 0, 0, x)


# ---------------------------------------------------------------------------
# i-softmax (I-BERT Alg. 3)
# ---------------------------------------------------------------------------


def i_softmax(q: jax.Array, scale: jax.Array, axis: int = -1,
              where=None) -> Tuple[jax.Array, jax.Array]:
    """Integer softmax. q int32 (<= ACT_QMAX range), scale f32.

    Returns (q_p int32 in [0, 2^SOFTMAX_OUT_BITS], S_out = 2^-SOFTMAX_OUT_BITS).
    `where`: optional bool mask (False entries get probability 0) — used by
    the no-padding / packed-sequence path (paper §7.1).
    """
    if where is not None:
        # masked positions -> most negative value (exp -> 0 after clamp)
        neg = jnp.full_like(q, jnp.iinfo(jnp.int32).min // 2)
        q = jnp.where(where, q, neg)
    q_max = jnp.max(q, axis=axis, keepdims=True)
    q_exp, _ = i_exp(q - q_max, scale)
    if where is not None:
        q_exp = jnp.where(where, q_exp, 0)
    q_sum = jnp.sum(q_exp, axis=axis, keepdims=True)  # <= len*q_exp_max; see note
    q_sum = jnp.maximum(q_sum, 1)

    # int32-safe normalization: scale sum into < 2^16, then fixed-point divide
    sh = jnp.maximum(
        jnp.ceil(jnp.log2(q_sum.astype(jnp.float32) + 1.0)) - 16, 0
    ).astype(jnp.int32)
    q_e2 = q_exp >> sh
    q_s2 = jnp.maximum(q_sum >> sh, 1)
    factor = (2 ** 29) // q_s2  # < 2^14 when q_s2 >= 2^15; <= 2^29 floor-safe
    prod = q_e2 * factor  # q_e2 <= q_s2 <= 2^16, factor*q_e2 <= 2^29 * (e2/s2)
    q_out = prod >> (29 - SOFTMAX_OUT_BITS)
    s_out = jnp.float32(2.0 ** (-SOFTMAX_OUT_BITS))
    return q_out.astype(jnp.int32), s_out


# ---------------------------------------------------------------------------
# i-erf / i-GELU (I-BERT Alg. 1)
# ---------------------------------------------------------------------------


def i_erf(q: jax.Array, scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.asarray(scale, jnp.float32)
    q_sgn = jnp.sign(q).astype(jnp.int32)
    q_abs = jnp.abs(q)
    q_b = _to_i32(jnp.floor(-_ERF_B / scale))  # positive
    q_clip = jnp.minimum(q_abs, q_b)
    q_c = _to_i32(jnp.floor(_ERF_C / (_ERF_A * scale * scale)))  # negative
    t = q_clip - q_b  # <= 0
    q_l = t * t + q_c  # scale a*S^2 (a<0 -> value in [-1, 0] * sign flip)
    s_l = _ERF_A * scale * scale
    return q_sgn * q_l, s_l


def i_gelu(q: jax.Array, scale: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Integer GELU.  q int32 within ACT_BITS range, scale f32.

    GELU(x) = x * Phi(x) with Phi = 0.5(1+erf(x/sqrt2)) in [0,1].  The Phi
    integer (q_erf + q_one, scale s_erf < 0, so the integer is <= 0) can reach
    ~2/|s_erf| ~ 5e8 for small scales; a dynamic arithmetic right-shift `g`
    renormalizes it below 2^19 so the final product with |q| <= 2^11 stays
    within int32.  The shift amount is derived from a scalar max — the same
    one-float-op-per-tensor budget the paper's Quant blocks spend.
    """
    scale = jnp.asarray(scale, jnp.float32)
    q_erf, s_erf = i_erf(q, scale / math.sqrt(2.0))
    q_one = _to_i32(jnp.floor(1.0 / s_erf))  # negative (s_erf < 0)
    t = q_erf + q_one  # <= 0; value t*s_erf = 1+erf in [0, 2]
    # analytic bound |t| <= 2/|s_erf| (no data reduction -> kernel is elementwise)
    tmax = 2.0 / jnp.abs(s_erf)
    g = jnp.maximum(jnp.ceil(jnp.log2(tmax + 1.0)) - 19.0, 0.0).astype(jnp.int32)
    q_phi = t >> g  # |q_phi| < 2^19 (arithmetic shift: floor, consistent)
    q_out = q * q_phi  # |q| <= 2^11 -> |prod| < 2^30
    s_out = scale * s_erf * jnp.exp2(g.astype(jnp.float32)) / 2.0
    return q_out.astype(jnp.int32), s_out


# ---------------------------------------------------------------------------
# i-LayerNorm (I-BERT §3.3; paper Fig. 10 LayerNorm modules)
# ---------------------------------------------------------------------------


class LNParams(NamedTuple):
    q_gamma: jax.Array  # int8-range int32, per-channel
    s_gamma: jax.Array  # f32 scalar
    q_beta: jax.Array  # int32, at scale s_out = 2^-LN_NORM_SHIFT * s_gamma
    s_out: jax.Array  # f32 scalar


def layernorm_prepare(gamma: jax.Array, beta: jax.Array) -> LNParams:
    """Offline float->integer parameter prep (weights side)."""
    s_g = jnp.maximum(jnp.max(jnp.abs(gamma)), 1e-8) / 127.0
    q_g = jnp.clip(_round_half_away(gamma / s_g), -127, 127).astype(jnp.int32)
    s_out = jnp.float32(2.0 ** (-LN_NORM_SHIFT)) * s_g
    q_b = _round_half_away(beta / s_out).astype(jnp.int32)
    return LNParams(q_g, jnp.asarray(s_g, jnp.float32), q_b, jnp.asarray(s_out, jnp.float32))


def i_layernorm(q8: jax.Array, prep: LNParams, axis: int = -1
                ) -> Tuple[jax.Array, jax.Array]:
    """Integer LayerNorm over `axis`.  Input must be int8-range int32.

    LayerNorm is scale-invariant, so the input scale cancels and is not
    needed.  Bounds (|q8|<=127, H<=8192): sum<=1.05e6, qc^2<=64516,
    sum(qc^2)<=5.3e8, var<<14 <= 2^31 guarded by var<=2^16.
    """
    q = q8.astype(jnp.int32)
    h = q.shape[axis]
    mean = jnp.sum(q, axis=axis, keepdims=True) // h
    qc = q - mean  # |qc| <= 255
    var = jnp.sum(qc * qc, axis=axis, keepdims=True) // h  # <= 65025
    std_s = i_sqrt(var << 14)  # ~ std * 2^7 ; var<<14 <= 1.07e9 < 2^31
    std_s = jnp.maximum(std_s, 1)
    # qc * 2^(LN_NORM_SHIFT+7) / (std*2^7) = (qc/std) * 2^LN_NORM_SHIFT
    norm = (qc * (1 << (LN_NORM_SHIFT + 7))) // std_s
    y = norm * prep.q_gamma + prep.q_beta  # |norm|<=~sqrt(H)*2^11, *127 < 2^31
    return y.astype(jnp.int32), prep.s_out


# ---------------------------------------------------------------------------
# float oracles (for property tests: how close is integer to real math)
# ---------------------------------------------------------------------------


def f_gelu(x):
    return x * 0.5 * (1.0 + jax.scipy.special.erf(x / math.sqrt(2.0)))


def f_softmax(x, axis=-1):
    return jax.nn.softmax(x, axis=axis)


def f_layernorm(x, gamma, beta, axis=-1):
    mu = jnp.mean(x, axis=axis, keepdims=True)
    var = jnp.var(x, axis=axis, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-12) * gamma + beta
