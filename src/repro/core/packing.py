"""No-padding adaptation (paper §7.1): buckets + sequence packing.

The paper's PEs iterate only over real tokens, padding just to
NUM_PE * ceil(M / NUM_PE).  Under XLA's static shapes the equivalents are:

  * `bucket_len`: round a length up to the hardware tile (128 = MXU lanes,
    standing in for NUM_PE) and pick the smallest pre-compiled bucket —
    minimum padding, one compiled program per bucket.
  * `pack_sequences`: first-fit-decreasing packing of many short sequences
    into fixed (B, S) rows with segment_ids + per-token positions; attention
    masks cross-segment pairs (models/attention.py), so no FLOPs are spent
    attending across packed neighbors and utilization ~= sum(len)/B*S.
  * `AdmissionPolicy`: per-slot bucket admission ordering for the
    continuous-batching serving engine (docs/serving.md) — deadline-overdue
    FIFO first, then warm (already-compiled) buckets.

Both are exercised by the Table-3/Table-4 benchmarks (padding vs no-padding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

LANE = 128  # MXU lane width == the paper's NUM_PE rounding granularity


def bucket_len(length: int, buckets: Sequence[int] = (), lane: int = LANE
               ) -> int:
    """Minimum padded length: smallest bucket >= length, else lane-rounded."""
    for b in sorted(buckets):
        if length <= b:
            return b
    return ((length + lane - 1) // lane) * lane


@dataclass
class Packed:
    tokens: np.ndarray  # (B, S) int32, 0-padded
    segment_ids: np.ndarray  # (B, S) int32, -1 on padding
    positions: np.ndarray  # (B, S) int32, position within own segment
    n_segments: int

    @property
    def utilization(self) -> float:
        return float((self.segment_ids >= 0).mean())


def pack_sequences(seqs: List[np.ndarray], row_len: int) -> Packed:
    """First-fit-decreasing packing into rows of row_len."""
    order = sorted(range(len(seqs)), key=lambda i: -len(seqs[i]))
    rows: List[List[int]] = []  # seq indices per row
    space: List[int] = []
    for i in order:
        n = len(seqs[i])
        if n > row_len:
            raise ValueError(f"sequence {i} (len {n}) exceeds row {row_len}")
        placed = False
        for rix in range(len(rows)):
            if space[rix] >= n:
                rows[rix].append(i)
                space[rix] -= n
                placed = True
                break
        if not placed:
            rows.append([i])
            space.append(row_len - n)

    b = len(rows)
    tokens = np.zeros((b, row_len), np.int32)
    seg = np.full((b, row_len), -1, np.int32)
    pos = np.zeros((b, row_len), np.int32)
    sid = 0
    for rix, members in enumerate(rows):
        cur = 0
        for i in members:
            n = len(seqs[i])
            tokens[rix, cur:cur + n] = seqs[i]
            seg[rix, cur:cur + n] = sid
            pos[rix, cur:cur + n] = np.arange(n)
            cur += n
            sid += 1
    return Packed(tokens, seg, pos, n_segments=sid)


@dataclass
class AdmissionPolicy:
    """Per-slot bucket admission for the continuous-batching engine.

    Admission is work-conserving: whenever a slot is free and a request has
    arrived, something is admitted (no holding slots back to fill a bucket —
    the paper's pipeline never waits for a wave, §8.2).  The policy only
    decides *order*:

      * requests whose queue wait exceeds the deadline go first, FIFO
        (runtime/stragglers.AdmissionDeadline — the deadline that used to
        launch partial waves now bounds admission reordering);
      * otherwise requests whose bucket is already compiled ("warm") are
        preferred, so steady-state admission never stalls the decode loop
        on a prefill compile;
      * ties break FIFO.

    `deadline` is any object with ``overdue(wait_s) -> bool``.
    """

    buckets: Sequence[int]
    lane: int = 8
    deadline: object = None

    def bucket_of(self, prompt_len: int) -> int:
        return bucket_len(prompt_len, self.buckets, lane=self.lane)

    def select(self, waiting: Sequence, n_free: int, warm=(),
               now: float = 0.0) -> List[int]:
        """Indices into `waiting` (arrival order) to admit, at most n_free.

        Each element of `waiting` needs `.prompt` and `.t_arrival` (seconds,
        relative to the same clock as `now`).
        """
        warm = set(warm)

        def key(ix: int):
            r = waiting[ix]
            wait = now - r.t_arrival
            if self.deadline and self.deadline.overdue(wait):
                return (0, 0, ix)  # overdue: strict FIFO, warmth ignored
            cold = self.bucket_of(len(r.prompt)) not in warm
            return (1, 1 if cold else 0, ix)

        return sorted(range(len(waiting)), key=key)[:n_free]


def padded_batch(seqs: List[np.ndarray], row_len: int) -> Packed:
    """The baseline the paper compares against: one sequence per row,
    padded to the maximum length."""
    b = len(seqs)
    tokens = np.zeros((b, row_len), np.int32)
    seg = np.full((b, row_len), -1, np.int32)
    pos = np.zeros((b, row_len), np.int32)
    for i, s in enumerate(seqs):
        n = len(s)
        tokens[i, :n] = s
        seg[i, :n] = i
        pos[i, :n] = np.arange(n)
    return Packed(tokens, seg, pos, n_segments=b)
