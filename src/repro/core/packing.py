"""No-padding adaptation (paper §7.1): buckets + sequence packing.

The paper's PEs iterate only over real tokens, padding just to
NUM_PE * ceil(M / NUM_PE).  Under XLA's static shapes the equivalents are:

  * `bucket_len`: round a length up to the hardware tile (128 = MXU lanes,
    standing in for NUM_PE) and pick the smallest pre-compiled bucket —
    minimum padding, one compiled program per bucket.
  * `pack_sequences`: first-fit-decreasing packing of many short sequences
    into fixed (B, S) rows with segment_ids + per-token positions; attention
    masks cross-segment pairs (models/attention.py), so no FLOPs are spent
    attending across packed neighbors and utilization ~= sum(len)/B*S.
  * `AdmissionPolicy`: per-slot bucket admission ordering for the
    continuous-batching serving engine (docs/serving.md) — deadline-overdue
    FIFO first, then warm (already-compiled) buckets.
  * `PagePool` / `RadixPrefixCache`: the paged-KV analogues — a
    reference-counted free-list allocator over the global KV page arena and
    a page-granular radix tree that lets requests sharing a prompt prefix
    reuse its KV pages copy-free (docs/serving.md §paged KV).

Both are exercised by the Table-3/Table-4 benchmarks (padding vs no-padding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

LANE = 128  # MXU lane width == the paper's NUM_PE rounding granularity


def bucket_len(length: int, buckets: Sequence[int] = (), lane: int = LANE
               ) -> int:
    """Minimum padded length: smallest bucket >= length, else lane-rounded."""
    for b in sorted(buckets):
        if length <= b:
            return b
    return ((length + lane - 1) // lane) * lane


@dataclass
class Packed:
    tokens: np.ndarray  # (B, S) int32, 0-padded
    segment_ids: np.ndarray  # (B, S) int32, -1 on padding
    positions: np.ndarray  # (B, S) int32, position within own segment
    n_segments: int

    @property
    def utilization(self) -> float:
        return float((self.segment_ids >= 0).mean())


def pack_sequences(seqs: List[np.ndarray], row_len: int) -> Packed:
    """First-fit-decreasing packing into rows of row_len."""
    order = sorted(range(len(seqs)), key=lambda i: -len(seqs[i]))
    rows: List[List[int]] = []  # seq indices per row
    space: List[int] = []
    for i in order:
        n = len(seqs[i])
        if n > row_len:
            raise ValueError(f"sequence {i} (len {n}) exceeds row {row_len}")
        placed = False
        for rix in range(len(rows)):
            if space[rix] >= n:
                rows[rix].append(i)
                space[rix] -= n
                placed = True
                break
        if not placed:
            rows.append([i])
            space.append(row_len - n)

    b = len(rows)
    tokens = np.zeros((b, row_len), np.int32)
    seg = np.full((b, row_len), -1, np.int32)
    pos = np.zeros((b, row_len), np.int32)
    sid = 0
    for rix, members in enumerate(rows):
        cur = 0
        for i in members:
            n = len(seqs[i])
            tokens[rix, cur:cur + n] = seqs[i]
            seg[rix, cur:cur + n] = sid
            pos[rix, cur:cur + n] = np.arange(n)
            cur += n
            sid += 1
    return Packed(tokens, seg, pos, n_segments=sid)


@dataclass
class AdmissionPolicy:
    """Per-slot bucket admission for the continuous-batching engine.

    Admission is work-conserving: whenever a slot is free and a request has
    arrived, something is admitted (no holding slots back to fill a bucket —
    the paper's pipeline never waits for a wave, §8.2).  The policy only
    decides *order*:

      * requests whose queue wait exceeds the deadline go first, FIFO
        (runtime/stragglers.AdmissionDeadline — the deadline that used to
        launch partial waves now bounds admission reordering);
      * otherwise requests whose bucket is already compiled ("warm") are
        preferred, so steady-state admission never stalls the decode loop
        on a prefill compile;
      * ties break FIFO.

    `deadline` is any object with ``overdue(wait_s) -> bool``.
    """

    buckets: Sequence[int]
    lane: int = 8
    deadline: object = None

    def bucket_of(self, prompt_len: int) -> int:
        return bucket_len(prompt_len, self.buckets, lane=self.lane)

    def select(self, waiting: Sequence, n_free: int, warm=(),
               now: float = 0.0) -> List[int]:
        """Indices into `waiting` (arrival order) to admit, at most n_free.

        Each element of `waiting` needs `.prompt` and `.t_arrival` (seconds,
        relative to the same clock as `now`).
        """
        warm = set(warm)

        def key(ix: int):
            r = waiting[ix]
            wait = now - r.t_arrival
            if self.deadline and self.deadline.overdue(wait):
                return (0, 0, ix)  # overdue: strict FIFO, warmth ignored
            cold = self.bucket_of(len(r.prompt)) not in warm
            return (1, 1 if cold else 0, ix)

        return sorted(range(len(waiting)), key=key)[:n_free]


class PagePool:
    """Free-list allocator over a global paged KV arena.

    The serving engine's HBM analogue of the paper's scarce on-chip URAM:
    KV capacity is a pool of fixed-size pages handed to requests on
    admission and returned on completion/preemption, so memory scales with
    *actual* sequence lengths instead of one worst-case slot row per lane.

    Pages are reference-counted: a page may be held by the lane that wrote
    it, by the radix prefix cache, and by any number of prefix-hit lanes
    simultaneously; it returns to the free list when the last reference
    drops.  Page 0 is reserved as the *trash page* and never allocated —
    inactive decode lanes scatter their masked writes there and unused
    page-table entries point at it, and since its `kpos` stay at the
    never-written sentinel it is unreachable by attention.
    """

    TRASH_PAGE = 0

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: recently-freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(1, num_pages))
        self._ref = np.zeros(num_pages, np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def pages_for(self, n_positions: int) -> int:
        """Pages needed to hold n_positions KV slots."""
        return -(-n_positions // self.page_size)

    def alloc(self, n: int) -> List[int]:
        """Take n pages (refcount 1 each); raises if the pool can't cover
        it — callers gate admission on `free_pages` / evict first."""
        if n > len(self._free):
            raise MemoryError(f"PagePool: want {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._ref[pages] += 1
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert 0 < p < self.num_pages and self._ref[p] > 0, p
            self._ref[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages that freed."""
        freed = []
        for p in pages:
            assert 0 < p < self.num_pages and self._ref[p] > 0, p
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def refcount(self, page: int) -> int:
        return int(self._ref[page])


class _RadixNode:
    __slots__ = ("children", "parent", "key", "page", "last_used")

    def __init__(self, parent=None, key=None, page: int = -1):
        self.children = {}  # page-of-tokens tuple -> _RadixNode
        self.parent = parent
        self.key = key
        self.page = page
        self.last_used = 0


class RadixPrefixCache:
    """Page-granular radix tree mapping prompt prefixes to arena pages.

    Each edge is one *full page* of prompt tokens (`page_size` of them) and
    each node owns one tree reference on the arena page holding that KV.
    Requests whose prompts share a system/common prefix therefore reuse the
    prefix KV copy-free: a lookup hands back the shared pages (incref'd for
    the caller) and the engine skips prefill for the covered positions.

    Copy-on-write is free by page alignment: a hit always covers a
    page-aligned prefix strictly shorter than the prompt, so every position
    a sharing lane will ever *write* (suffix ingest + decode) lands in
    pages the lane owns exclusively — shared pages are only ever read.

    Eviction is LRU over evictable leaves (no children, no live lane
    references) and only runs under pool pressure, so a cached prefix
    survives as long as capacity allows.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.root = _RadixNode()
        self._clock = 0
        self._nodes = 0
        self.hits = 0
        self.lookups = 0

    def _page_key(self, tokens: np.ndarray, j: int):
        ps = self.pool.page_size
        return tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])

    def lookup(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached page-aligned prefix of `tokens`, capped at
        len(tokens) - 1 positions so at least the final prompt token is
        always re-ingested (its forward pass produces the first logits).

        Returns (pages, hit_len).  The caller owns one new reference on
        each returned page (released via `pool.decref` when the lane
        finishes)."""
        ps = self.pool.page_size
        max_pages = max(len(tokens) - 1, 0) // ps
        self._clock += 1
        self.lookups += 1
        node, pages = self.root, []
        for j in range(max_pages):
            child = node.children.get(self._page_key(tokens, j))
            if child is None:
                break
            child.last_used = self._clock
            pages.append(child.page)
            node = child
        if pages:
            self.pool.incref(pages)
            self.hits += 1
        return pages, len(pages) * ps

    def peek(self, tokens: np.ndarray) -> int:
        """Length (in positions) of the cached page-aligned prefix a
        `lookup` of `tokens` would return, WITHOUT taking references,
        bumping the LRU clock, or counting a hit/lookup.  Advisory only —
        the answer can change before an admission actually calls
        `lookup` — used by the disaggregated scheduler to classify queued
        requests into the prefill vs decode-ingest queue."""
        ps = self.pool.page_size
        max_pages = max(len(tokens) - 1, 0) // ps
        node, n = self.root, 0
        for j in range(max_pages):
            child = node.children.get(self._page_key(tokens, j))
            if child is None:
                break
            n += 1
            node = child
        return n * ps

    def insert(self, tokens: np.ndarray, pages: Sequence[int]) -> int:
        """Register a prompt's fully-covered pages; ``pages[j]`` must back
        positions [j*ps, (j+1)*ps).  Pages already on the walk are left as
        the canonical copy (the caller's duplicate stays lane-private);
        newly registered pages gain one tree reference.  Returns the number
        of newly registered pages."""
        ps = self.pool.page_size
        n_full = len(tokens) // ps  # only pages the prompt fills completely
        self._clock += 1
        node, added = self.root, 0
        for j in range(min(n_full, len(pages))):
            key = self._page_key(tokens, j)
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(parent=node, key=key, page=pages[j])
                node.children[key] = child
                self.pool.incref([pages[j]])
                self._nodes += 1
                added += 1
            child.last_used = self._clock
            node = child
        return added

    def _evictable_leaves(self) -> List[_RadixNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif self.pool.refcount(n.page) == 1:  # tree-only reference
                out.append(n)
        return out

    def evict(self, n_pages: int) -> int:
        """Free at least n_pages by LRU leaf eviction; returns pages
        actually freed (may be fewer if everything left is shared)."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            leaves.sort(key=lambda nd: nd.last_used)
            for nd in leaves:
                nd.parent.children.pop(nd.key)
                self._nodes -= 1
                freed += len(self.pool.decref([nd.page]))
                if freed >= n_pages:
                    break
        return freed

    @property
    def cached_pages(self) -> int:
        return self._nodes


def padded_batch(seqs: List[np.ndarray], row_len: int) -> Packed:
    """The baseline the paper compares against: one sequence per row,
    padded to the maximum length."""
    b = len(seqs)
    tokens = np.zeros((b, row_len), np.int32)
    seg = np.full((b, row_len), -1, np.int32)
    pos = np.zeros((b, row_len), np.int32)
    for i, s in enumerate(seqs):
        n = len(s)
        tokens[i, :n] = s
        seg[i, :n] = i
        pos[i, :n] = np.arange(n)
    return Packed(tokens, seg, pos, n_segments=b)
