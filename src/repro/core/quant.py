"""Symmetric integer quantization arithmetic (the paper's C4 substrate).

The paper's hardware keeps INT8 operands, INT32 matmul accumulators, and a
"Quant" module converting INT32→INT8 after every matrix multiply.  I-BERT's
software reference does the same: integer tensors carry a float32 *scale*
(per-tensor or per-channel); all heavy math is integer, scaling is the only
float touch-point.  We mirror that contract exactly so the Pallas kernels and
the pure-jnp oracles agree bit-for-bit.

Deviation noted in DESIGN.md: the fixed-point (M0, shift) dyadic multiplier
used by some integer inference stacks needs 64-bit intermediates which Pallas
TPU integer units do not expose; both kernel and reference therefore use
float-scale requantization with round-half-away-from-zero, which is what the
published I-BERT code does too.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -127, 127  # symmetric: -128 excluded, as in I-BERT


class QTensor(NamedTuple):
    """Integer values + float scale: real = values * scale."""

    values: jax.Array  # int8 or int32
    scale: jax.Array  # f32 scalar or per-channel (broadcastable)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self) -> jax.Array:
        return self.values.astype(jnp.float32) * self.scale


def _round_half_away(x: jax.Array) -> jax.Array:
    """Round half away from zero (I-BERT / TFLite rounding)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def compute_scale(x: jax.Array, axis: Optional[int] = None, bits: int = 8) -> jax.Array:
    """Symmetric scale from dynamic range. axis=None -> per-tensor."""
    qmax = 2.0 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize(x: jax.Array, scale: Optional[jax.Array] = None, axis: Optional[int] = None,
             bits: int = 8) -> QTensor:
    if scale is None:
        scale = compute_scale(x, axis=axis, bits=bits)
    qmax = 2 ** (bits - 1) - 1
    q = _round_half_away(x / scale)
    q = jnp.clip(q, -qmax, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return QTensor(q.astype(dtype), jnp.asarray(scale, jnp.float32))


def requantize(acc: jax.Array, scale_in: jax.Array, scale_out: jax.Array) -> jax.Array:
    """INT32 accumulator (scale_in) -> INT8 (scale_out). The paper's Quant module."""
    ratio = scale_in / scale_out
    q = _round_half_away(acc.astype(jnp.float32) * ratio)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def int8_matmul_ref(a: QTensor, b: QTensor, scale_out: Optional[jax.Array] = None):
    """INT8 x INT8 -> INT32 matmul, optionally requantized to INT8.

    Pure-jnp contract shared with kernels/int8_matmul.py: accumulate in int32
    via preferred_element_type (MXU-native on TPU).
    """
    acc = jax.lax.dot_general(
        a.values, b.values,
        (((a.values.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale_acc = a.scale * b.scale
    if scale_out is None:
        return QTensor(acc, scale_acc)
    return QTensor(requantize(acc, scale_acc, scale_out), scale_out)


def kv_quantize(x: jax.Array, bits: int = 8):
    """Per-row symmetric int8 over the last axis for KV-cache storage.

    x: (..., hd) float; returns (int8 values of x.shape, f32 scales of
    x.shape[:-1]).  One scale per cache row per kv head keeps the
    quantization error independent across positions — a page shared by
    many lanes (radix prefix reuse) carries its scales *in the arena*, so
    every reader dequantizes identically and prefix-hit streams stay
    bit-identical to cold prefills.  Round-half-away matches `quantize`
    (and the I-BERT hardware), so |x - dequant| <= scale/2 elementwise.
    """
    qmax = 2.0 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8)
    s = amax / qmax
    q = jnp.clip(_round_half_away(xf / s[..., None]), -qmax, qmax)
    return q.astype(jnp.int8), s


def kv_dequantize(q: jax.Array, s: jax.Array) -> jax.Array:
    """Inverse of `kv_quantize`: (..., hd) int8 + (...,) f32 -> f32."""
    return q.astype(jnp.float32) * s[..., None]


def fake_quant(x: jax.Array, axis: Optional[int] = None, bits: int = 8) -> jax.Array:
    """Quantize-dequantize (used for QAT-style parity checks)."""
    q = quantize(x, axis=axis, bits=bits)
    return q.dequantize()
