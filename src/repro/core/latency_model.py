"""The paper's pipeline latency model (§8.2.2, Eq. 1 and Fig. 19).

  T_total(L) = T + (L - 1) * (X + d)

  T : latency of one encoder (first input -> last output)
  X : time until an encoder emits its FIRST output packet
  d : inter-cluster network hop (switch) latency
  L : number of serially-connected encoder clusters

The paper measures X, T, I (packet interval) per sequence length on the
6-FPGA proof-of-concept (Table 1), then projects the 72-FPGA full model
(Table 2) and the Versal variant (§9, X ~= 0.53 T).  We reproduce the same
methodology: benchmarks measure our per-encoder T and X, fit the model, and
the roofline module plays §9's role of projecting onto target hardware.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence


@dataclass(frozen=True)
class StageTiming:
    T: float  # per-stage total latency (s)
    X: float  # time-to-first-output (s)
    d: float  # inter-stage hop latency (s)
    I: float = 0.0  # steady-state output interval (s)


def total_latency(t: StageTiming, n_stages: int) -> float:
    """Eq. 1."""
    return t.T + (n_stages - 1) * (t.X + t.d)


def throughput(t: StageTiming, items_per_stage_pass: int = 1) -> float:
    """Steady-state items/s: the pipeline drains at the slowest stage rate
    (paper §8.2.3: 'overall throughput should be the same as the layers with
    the lowest throughput')."""
    return items_per_stage_pass / max(t.T, 1e-12)


def fit_x_fraction(x_values: Sequence[float], t_values: Sequence[float]
                   ) -> float:
    """X as a fraction of T (the paper's §9 uses X ~= 0.53 T at seq 128)."""
    num = sum(x * t for x, t in zip(x_values, t_values))
    den = sum(t * t for t in t_values)
    return num / max(den, 1e-12)


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) — the TPU-side equivalent of Eq. 1's
    fill/drain overhead; used to pick microbatch counts in train.py."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_ticks_per_step(n_stages: int, exact: bool) -> int:
    """Stage-passes one decode step costs on an S-stage serving pipeline.

    exact (drained GPipe schedule, docs/serving.md): every lane's token
    must traverse all S stages and the pipeline drains before the next
    step — 2S-1 ticks.  Throughput (request-skewed schedule): each stage
    advances its own lane group every tick, so a full rotation emits one
    token per lane in S ticks with no drain bubble.  Used by
    core/plan_search to price `serve_pipeline` candidates."""
    if n_stages <= 1:
        return 1
    return 2 * n_stages - 1 if exact else n_stages


def decode_step_latency(t_stage: float, n_stages: int, d: float,
                        exact: bool) -> float:
    """One decode tick through the pipeline: Eq. 1 with X=T per stage
    (a single-token step emits its output only when the stage finishes),
    scaled by the schedule's ticks-per-step."""
    ticks = pipeline_ticks_per_step(n_stages, exact)
    return ticks * (t_stage + d)


def estimate_table2(t_by_seq: Dict[int, float], x_by_seq: Dict[int, float],
                    d: float, n_stages: int) -> Dict[int, float]:
    """Reproduce the structure of the paper's Table 2 from measured T/X."""
    return {
        s: total_latency(StageTiming(T=t_by_seq[s], X=x_by_seq[s], d=d),
                         n_stages)
        for s in t_by_seq
    }
