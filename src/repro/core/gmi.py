"""GMI — the Galapagos Messaging Interface (paper §5), on mesh axes.

The paper defines a minimal collective set (Broadcast / Reduce / Scatter /
Gather) plus composition ("Allgather = Gather to a root, then Broadcast",
§5.1) and two communicator levels: intra-cluster and inter-cluster, where
ALL inter-cluster traffic funnels through a Gateway kernel (§4).

TPU mapping (DESIGN.md §2):
  * communicator  = named mesh axis (or tuple of axes) inside shard_map
  * intra-cluster = `data`/`model` axes;  inter-cluster = `pod` axis
  * gateway       = the two-phase hierarchical schedule: intra-pod
    reduce-scatter, inter-pod exchange between same-index shard leaders only,
    intra-pod all-gather.  Each device talks across pods only to its
    same-index peer — the SPMD expression of "kernel 0 forwards everything".

Two implementations are provided for each All* collective:
  * `*_composed` — the paper-faithful root-based composition (C5 baseline)
  * the fused `lax` one-step collective (beyond-paper optimized)
§Perf compares their collective-byte counts from lowered HLO.

All functions must be called inside shard_map (they use lax collectives).
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

Axis = Union[str, Sequence[str]]


def _one_axis_size(a: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(a)
    return lax.psum(1, a)  # older jax: count members instead


def _index(axis: Axis) -> jax.Array:
    if isinstance(axis, str):
        return lax.axis_index(axis)
    idx = jnp.int32(0)
    for a in axis:
        idx = idx * _one_axis_size(a) + lax.axis_index(a)
    return idx


def axis_size(axis: Axis) -> int:
    if isinstance(axis, str):
        return _one_axis_size(axis)
    n = 1
    for a in axis:
        n *= _one_axis_size(a)
    return n


# -- the four GMI primitives -------------------------------------------------


def broadcast(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    """Every rank receives root's x.  (masked all-reduce: the standard SPMD
    expression of one-to-all; on ICI this lowers to a broadcast tree.)"""
    mask = (_index(axis) == root).astype(x.dtype)
    return lax.psum(x * mask, axis)


def reduce(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    """Sum of x over the group, valid only on root (others get zeros)."""
    r = lax.psum(x, axis)
    mask = (_index(axis) == root).astype(x.dtype)
    return r * mask


def gather(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    """Concatenate group members' x along a new leading dim, on root only."""
    g = lax.all_gather(x, axis, axis=0, tiled=False)
    if not isinstance(axis, str):
        n = axis_size(axis)
        g = g.reshape((n,) + x.shape)
    mask = (_index(axis) == root).astype(x.dtype)
    return g * mask


def scatter(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    """Root holds (group_size, ...); member i receives slice i."""
    full = broadcast(x, axis, root)
    return jnp.take(full, _index(axis), axis=0)


# -- composed All* (paper-faithful: via a root, §5.1) ------------------------


def allgather_composed(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    g = gather(x, axis, root)  # root has all
    return broadcast(g, axis, root)


def allreduce_composed(x: jax.Array, axis: Axis, root: int = 0) -> jax.Array:
    r = reduce(x, axis, root)
    return broadcast(r, axis, root)


# -- fused one-step collectives (optimized) ----------------------------------


def allgather(x: jax.Array, axis: Axis) -> jax.Array:
    g = lax.all_gather(x, axis, axis=0, tiled=False)
    if not isinstance(axis, str):
        g = g.reshape((axis_size(axis),) + x.shape)
    return g


def allreduce(x: jax.Array, axis: Axis) -> jax.Array:
    return lax.psum(x, axis)


def reduce_scatter(x: jax.Array, axis: Axis) -> jax.Array:
    """Sum over group, each member keeps its slice of leading dim."""
    return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)


# -- hierarchical (gateway) collectives: the clusters-of-clusters schedule ---


def hier_allreduce(x: jax.Array, intra_axis: Axis, inter_axis: Axis
                   ) -> jax.Array:
    """Gateway-style inter-cluster allreduce (paper §4).

    Phase 1: reduce-scatter inside the cluster (each member becomes the
    gateway for its shard).  Phase 2: the per-shard gateways all-reduce
    across clusters (1/N_intra of the naive inter-cluster bytes).  Phase 3:
    all-gather inside the cluster.
    """
    n = axis_size(intra_axis)
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    shard = lax.psum_scatter(xp, intra_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, inter_axis)
    full = lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[: x.shape[0]] if pad else full


def flat_allreduce(x: jax.Array, intra_axis: Axis, inter_axis: Axis
                   ) -> jax.Array:
    """Naive single-phase allreduce over both levels (the DFX-style baseline
    the paper argues against: every kernel talks to every cluster)."""
    axes = ((intra_axis,) if isinstance(intra_axis, str) else tuple(intra_axis))
    axes += (inter_axis,) if isinstance(inter_axis, str) else tuple(inter_axis)
    return lax.psum(x, axes)


def hier_allgather(x: jax.Array, intra_axis: Axis, inter_axis: Axis
                   ) -> jax.Array:
    """Gather within cluster, exchange across clusters via gateways, then
    broadcast within cluster — returns (n_inter, n_intra, ...) stacked."""
    intra = allgather(x, intra_axis)  # (n_intra, ...)
    inter = lax.all_gather(intra, inter_axis, axis=0, tiled=False)
    return inter


# -- point-to-point through the gateway (inter-cluster send, §5.2) -----------


def cluster_send(x: jax.Array, inter_axis: str, dst_offset: int = 1
                 ) -> jax.Array:
    """Send x to the next cluster along the ring (one-byte-header GMI
    inter-cluster message -> collective_permute on the pod axis)."""
    n = _one_axis_size(inter_axis)
    perm = [(i, (i + dst_offset) % n) for i in range(n)]
    return lax.ppermute(x, inter_axis, perm)
