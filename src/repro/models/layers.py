"""Shared float layers: norms, RoPE, MLPs, embeddings, chunked cross-entropy.

Parameters are plain pytrees (nested dicts of jnp arrays) so the Cluster
Builder can attach PartitionSpecs by path without framework indirection.
Compute dtype is bf16 with f32 reductions (softmax/norm/loss), the standard
TPU mixed-precision contract.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(
        COMPUTE_DTYPE
    )


def dense(x: jax.Array, w) -> jax.Array:
    if isinstance(w, dict) and "q" in w:  # int8 serving path (§Perf C)
        from repro.models.quantized import qdense
        return qdense(x, w)
    return jnp.einsum("...d,df->...f", x, w)


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(x: jax.Array, p: Params, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * p["g"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(x: jax.Array, p: Params, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]
    return y.astype(x.dtype)


def norm_init(cfg, d: Optional[int] = None):
    d = d or cfg.d_model
    return layernorm_init(d) if cfg.norm == "layernorm" else rmsnorm_init(d)


def norm(x, p, cfg):
    return layernorm(x, p) if cfg.norm == "layernorm" else rmsnorm(x, p)


# -- RoPE -------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP --------------------------------------------------------------------


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def mlp_init(key, cfg, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], cfg.d_model, d_ff),
         "wo": dense_init(ks[1], d_ff, cfg.d_model)}
    if cfg.mlp_style == "swiglu":
        p["wg"] = dense_init(ks[2], cfg.d_model, d_ff)
    return p


def mlp(x: jax.Array, p: Params, cfg) -> jax.Array:
    from repro.models.shard_hints import fsdp_int8_gather, hint

    a = act_fn(cfg.act)
    wi = fsdp_int8_gather(p["wi"], tp_dim=1)  # no-op unless enabled
    wo = fsdp_int8_gather(p["wo"], tp_dim=0)
    h = hint(dense(x, wi), "btf")
    if cfg.mlp_style == "swiglu":
        h = a(hint(dense(x, fsdp_int8_gather(p["wg"], tp_dim=1)), "btf")) * h
    else:
        h = a(h)
    # serve_exact plans gather the f-sharded activation so the replicated
    # down-projection is bit-exact (no psum); serve_psum plans keep it
    # f-sharded for the column-sharded wo (partial dot + one all-reduce);
    # no-ops everywhere else
    return dense(hint(hint(h, "gather"), "psum"), wo)


# -- embedding / head -------------------------------------------------------


def embed_init(key, cfg) -> Params:
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32)
                 * 0.02).astype(COMPUTE_DTYPE)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model,
                               cfg.vocab_size, scale=0.02)
    return p


def embed(tokens: jax.Array, p: Params) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_head(h: jax.Array, p: Params) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    return jnp.einsum("...d,dv->...v", h, w).astype(jnp.float32)


# -- loss -------------------------------------------------------------------


def cross_entropy_chunked(h: jax.Array, labels: jax.Array, embed_p: Params,
                          chunk: int = 512) -> jax.Array:
    """Mean next-token CE without materializing (B,S,V) logits.

    Scans over sequence chunks: peak logits footprint is (B, chunk, V),
    which keeps the 256k-vocab archs within per-chip HBM (DESIGN.md §3).
    """
    b, s, _ = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = h.shape[1] // chunk
    hc = h.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        from repro.models.shard_hints import hint

        hx, lx = xs
        logits = hint(lm_head(hx, embed_p), "btv")  # (B, chunk, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1
        )[..., 0]
        valid = lx >= 0
        nll = jnp.where(valid, logz - gold, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)
