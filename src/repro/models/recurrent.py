"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and xLSTM cells.

These are the sub-quadratic archs that run the long_500k cell.  Training and
prefill use parallel forms (associative scan for RG-LRU, chunkwise-parallel
stabilized recurrence for mLSTM); decode is O(1)-state single-step.

Simplifications vs the source papers (noted in DESIGN.md): mLSTM omits the
pre-QK causal conv; block-diagonal per-head projections follow the xLSTM-1.3B
resource shape.  The chunked mLSTM carries the xLSTM max-stabilizer `m`
across chunks, so long sequences do not under/overflow.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.shard_hints import hint

Params = Dict[str, Any]

MLSTM_CHUNK = 128
_RGLRU_C = 8.0


# ===========================================================================
# RG-LRU (Griffin) recurrent block
# ===========================================================================


def rglru_init(key, cfg) -> Params:
    d, w, cw = cfg.d_model, cfg.rnn_width or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 7)
    # Lambda init so a = sigmoid(L) ^ c in [0.9, 0.999] (Griffin app. A)
    u = jax.random.uniform(ks[6], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1 / _RGLRU_C) / (1 - u ** (1 / _RGLRU_C)))
    return {
        "w_gate_in": dense_init(ks[0], d, w),
        "w_x_in": dense_init(ks[1], d, w),
        "conv": (jax.random.normal(ks[2], (cw, w), jnp.float32) * 0.02
                 ).astype(jnp.float32),
        "w_rgate": dense_init(ks[3], w, w),
        "w_igate": dense_init(ks[4], w, w),
        "w_out": dense_init(ks[5], w, d),
        "lam": lam,
    }


def _causal_conv(x: jax.Array, conv: jax.Array,
                 state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x:(B,S,W), conv:(cw,W), state:(B,cw-1,W)."""
    cw = conv.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)
    out = sum(ext[:, i : i + x.shape[1]] * conv[cw - 1 - i]
              for i in range(cw))
    new_state = ext[:, -(cw - 1):] if cw > 1 else state
    return out.astype(x.dtype), new_state


def _rglru_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis=1."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(x: jax.Array, p: Params, cfg,
                state: Optional[Params] = None,
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    """Griffin recurrent block. x:(B,S,D). state={'h','conv'} for decode.

    valid: (B,S) bool — False (pad) steps leave the state untouched
    (a=1, b=0), the recurrent form of the paper's no-padding rule."""
    gate = jax.nn.gelu(hint(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]),
                            "btf"))
    xi_raw = hint(jnp.einsum("bsd,dw->bsw", x, p["w_x_in"]), "btf")
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi_raw, p["conv"], conv_state)
    if valid is not None and state is not None and x.shape[1] > 1:
        # prefill with trailing pads: the decode conv state must hold the
        # last *valid* inputs, not the pad columns
        cw1 = p["conv"].shape[0] - 1
        lengths = jnp.sum(valid.astype(jnp.int32), axis=1)  # (B,)
        gather = jnp.maximum(
            lengths[:, None] - cw1 + jnp.arange(cw1)[None, :], 0)
        new_conv = jnp.take_along_axis(
            xi_raw, gather[..., None], axis=1).astype(new_conv.dtype)

    xf = xi.astype(jnp.float32)
    # gate matmuls emit bf16 (MXU accumulates f32 internally), so the TP
    # reduction at the contraction boundary moves bf16 not f32 (§Perf A2);
    # the sigmoid itself runs in f32
    r = jax.nn.sigmoid(hint(jnp.einsum("bsw,wv->bsv", xi, p["w_rgate"]),
                            "btf").astype(jnp.float32))
    i = jax.nn.sigmoid(hint(jnp.einsum("bsw,wv->bsv", xi, p["w_igate"]),
                            "btf").astype(jnp.float32))
    log_a = _RGLRU_C * r * jax.nn.log_sigmoid(p["lam"])  # (B,S,W) <= 0
    if valid is not None:
        log_a = jnp.where(valid[..., None], log_a, 0.0)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if valid is not None:
        b = jnp.where(valid[..., None], b, 0.0)

    if state is None:
        h = _rglru_scan(a, b)
        new_state = None
    elif x.shape[1] == 1:
        h = a * state["h"][:, None, :] + b  # decode
        new_state = {"h": h[:, -1], "conv": new_conv}
    else:
        # prefill-with-state: fold h0 into the first step, then scan
        b = b.at[:, 0].add(a[:, 0] * state["h"])
        h = _rglru_scan(a, b)
        new_state = {"h": h[:, -1], "conv": new_conv}
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", y, p["w_out"]), new_state


def init_rglru_state(cfg, batch: int):
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.bfloat16),
    }


# ===========================================================================
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel
# ===========================================================================


def mlstm_init(key, cfg) -> Params:
    d = cfg.d_model
    inner = int(cfg.proj_factor * d)
    nh = cfg.n_heads
    ih = inner // nh
    dk = ih // 2
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(ih)
    return {
        "up_z": dense_init(ks[0], d, inner),
        "up_g": dense_init(ks[1], d, inner),
        "wq": (jax.random.normal(ks[2], (nh, ih, dk)) * s).astype(jnp.bfloat16),
        "wk": (jax.random.normal(ks[3], (nh, ih, dk)) * s).astype(jnp.bfloat16),
        "wv": (jax.random.normal(ks[4], (nh, ih, ih)) * s).astype(jnp.bfloat16),
        "w_if": dense_init(ks[5], d, 2 * nh),  # input & forget gate logits
        "down": dense_init(ks[6], inner, d),
    }


def _mlstm_chunk(q, k, v, li, lf, carry):
    """One chunk of stabilized mLSTM.

    q,k:(B,T,nh,dk) v:(B,T,nh,ih) li/lf:(B,T,nh) logs.
    carry = (C:(B,nh,dk,ih), n:(B,nh,dk), m:(B,nh)).
    """
    C, n, m = carry
    bsz, t, nh, dk = q.shape
    b = jnp.cumsum(lf, axis=1)  # (B,T,nh) inclusive cumsum of logsigmoid(f)
    # log intra decay D_ij = b_i - b_j + li_j (j <= i)
    dmat = b[:, :, None] - b[:, None, :] + li[:, None, :]  # (B,T,T,nh)
    causal = jnp.tril(jnp.ones((t, t), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m_intra = jnp.max(dmat, axis=2)  # (B,T,nh)
    m_inter = b + m[:, None, :]
    m_i = jnp.maximum(m_inter, m_intra)  # running stabilizer per step

    dint = jnp.exp(dmat - m_i[:, :, None])  # (B,T,T,nh)
    s = jnp.einsum("binK,bjnK->bijn", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    sw = s * dint
    num = jnp.einsum("bijn,bjnh->binh", sw, v.astype(jnp.float32))
    den = jnp.sum(sw, axis=2)  # (B,T,nh): sum_j D~_ij (q_i . k_j)

    winter = jnp.exp(m_inter - m_i)  # (B,T,nh)
    num = num + winter[..., None] * jnp.einsum(
        "binK,bnKh->binh", q.astype(jnp.float32), C)
    den = den + winter * jnp.einsum("binK,bnK->bin", q.astype(jnp.float32), n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

    # carry update
    bt = b[:, -1]  # (B,nh) total log decay of chunk
    lj = bt[:, None, :] - b + li  # (B,T,nh): log decay from j to chunk end
    m_new = jnp.maximum(m + bt, jnp.max(lj, axis=1))
    wj = jnp.exp(lj - m_new[:, None, :])  # (B,T,nh)
    C_new = (jnp.exp(m + bt - m_new)[:, :, None, None] * C
             + jnp.einsum("bjn,bjnK,bjnh->bnKh", wj, k.astype(jnp.float32),
                          v.astype(jnp.float32)))
    n_new = (jnp.exp(m + bt - m_new)[:, :, None] * n
             + jnp.einsum("bjn,bjnK->bnK", wj, k.astype(jnp.float32)))
    return h, (C_new, n_new, m_new)


def mlstm_block(x: jax.Array, p: Params, cfg,
                state: Optional[Params] = None,
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    bsz, s, d = x.shape
    inner = int(cfg.proj_factor * d)
    nh = cfg.n_heads
    ih = inner // nh
    dk = ih // 2

    z = hint(jnp.einsum("bsd,di->bsi", x, p["up_z"]).reshape(
        bsz, s, nh, ih), "bsni")
    g = jax.nn.silu(hint(jnp.einsum("bsd,di->bsi", x, p["up_g"]), "btf"))
    q = hint(jnp.einsum("bsnh,nhk->bsnk", z, p["wq"]) / math.sqrt(dk), "state")
    k = hint(jnp.einsum("bsnh,nhk->bsnk", z, p["wk"]) / math.sqrt(dk), "state")
    v = hint(jnp.einsum("bsnh,nhj->bsnj", z, p["wv"]), "bsni")
    gates = hint(jnp.einsum("bsd,dg->bsg", x, p["w_if"]).astype(jnp.float32),
                 "state")
    li = gates[..., :nh]  # log input gate (i = exp(li))
    lf = jax.nn.log_sigmoid(gates[..., nh:])  # log forget gate
    if valid is not None:  # pads: f=1, i=0 -> state untouched
        li = jnp.where(valid[..., None], li, -1e30)
        lf = jnp.where(valid[..., None], lf, 0.0)

    carry0 = ((state["C"], state["n"], state["m"]) if state is not None else (
        jnp.zeros((bsz, nh, dk, ih), jnp.float32),
        jnp.zeros((bsz, nh, dk), jnp.float32),
        jnp.full((bsz, nh), -1e30, jnp.float32),
    ))
    if s > 1:
        carry = carry0
        chunk = min(MLSTM_CHUNK, s)
        pad = (-s) % chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
            lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        nc = q.shape[1] // chunk

        def to_chunks(a):
            return a.reshape(bsz, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

        def body(c, xs):
            qc, kc, vc, lic, lfc = xs
            h, c = _mlstm_chunk(qc, kc, vc, lic, lfc, c)
            c = tuple(hint(t, "state" if t.ndim < 4 else "bsni") for t in c)
            return c, h

        # checkpoint each chunk: bwd recomputes intra-chunk decay matrices
        # instead of saving (B,T,T,nh) residuals per chunk (§Perf)
        c_new, hs = jax.lax.scan(
            jax.checkpoint(body), carry,
            (to_chunks(q), to_chunks(k), to_chunks(v),
             to_chunks(li), to_chunks(lf)))
        h = hs.swapaxes(0, 1).reshape(bsz, nc * chunk, nh, ih)[:, :s]
    else:
        h, c_new = _mlstm_chunk(q, k, v, li, lf, carry0)
    new_state = (None if state is None else
                 {"C": c_new[0], "n": c_new[1], "m": c_new[2]})

    y = (g * h.reshape(bsz, s, inner).astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["down"]), new_state


def init_mlstm_state(cfg, batch: int):
    inner = int(cfg.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    ih = inner // nh
    dk = ih // 2
    return {
        "C": jnp.zeros((batch, nh, dk, ih), jnp.float32),
        "n": jnp.zeros((batch, nh, dk), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


# ===========================================================================
# sLSTM (xLSTM scalar-memory cell): inherently sequential
# ===========================================================================


def slstm_init(key, cfg) -> Params:
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    glu_d = int(4 * d / 3)
    return {
        "w_in": dense_init(ks[0], d, 4 * d),  # i,f,z,o
        "r": (jax.random.normal(ks[1], (4, nh, dh, dh)) / math.sqrt(dh)
              ).astype(jnp.float32),
        "glu_wi": dense_init(ks[2], d, 2 * glu_d),
        "glu_wo": dense_init(ks[3], glu_d, d),
    }


def _slstm_step(p, cfg, carry, x_t):
    """x_t: (B, 4d) pre-computed input contribution."""
    h, c, n, m = carry  # h,c,n: (B,nh,dh); m: (B,nh,dh)
    bsz = x_t.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    rec = jnp.einsum("bnh,gnhk->bgnk", h, p["r"])  # (B,4,nh,dh)
    raw = x_t.reshape(bsz, 4, nh, dh).astype(jnp.float32) + rec
    il, fl, zl, ol = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    lf = jax.nn.log_sigmoid(fl)
    m_new = jnp.maximum(lf + m, il)
    i = jnp.exp(il - m_new)
    f = jnp.exp(lf + m - m_new)
    c_new = f * c + i * jnp.tanh(zl)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(ol) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(x: jax.Array, p: Params, cfg,
                state: Optional[Params] = None,
                valid: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    bsz, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    # sLSTM cell does not tensor-parallelize: pin everything batch-sharded
    xin = hint(jnp.einsum("bsd,dg->bsg", x, p["w_in"]), "state")

    if state is None:
        carry = (jnp.zeros((bsz, nh, dh), jnp.float32),) * 3 + (
            jnp.full((bsz, nh, dh), -1e30, jnp.float32),)
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    if valid is None:
        valid_t = jnp.ones((s, bsz), bool)
    else:
        valid_t = valid.swapaxes(0, 1)

    def body(c, xs):
        x_t, v_t = xs
        nc = _slstm_step(p, cfg, c, x_t)
        nc = jax.tree.map(
            lambda new, old: jnp.where(v_t[:, None, None], new, old), nc, c)
        nc = tuple(hint(t, "state") for t in nc)
        return nc, nc[0]

    # checkpoint per step: sLSTM is sequential anyway; saving only the
    # (B,nh,dh) carries keeps 4k-step scans within HBM (§Perf)
    carry, hs = jax.lax.scan(jax.checkpoint(body), carry,
                             (xin.swapaxes(0, 1), valid_t))
    y = hs.swapaxes(0, 1).reshape(bsz, s, d).astype(x.dtype)
    new_state = (None if state is None else
                 {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]})

    # post-up GLU FFN (xLSTM sLSTM block)
    u = hint(jnp.einsum("bsd,dg->bsg", y, p["glu_wi"]), "btf")
    a, b = jnp.split(u, 2, axis=-1)
    y = jnp.einsum("bsg,gd->bsd", jax.nn.gelu(a) * b, p["glu_wo"])
    return y, new_state


def init_slstm_state(cfg, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, nh, dh), -1e30,
                                                  jnp.float32)}
