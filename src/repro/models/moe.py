"""Mixture-of-Experts FFN: top-k routing with capacity-based gather/scatter.

The paper's GMI Scatter/Gather collectives (§5, Fig. 6) are exactly the MoE
dispatch/combine pattern: a router scatters token blocks to expert kernels
and gathers their outputs.  We implement the TPU-native version: tokens are
ranked per expert (sort-free cumsum ranking), gathered into a dense
(groups, experts, capacity, d_model) layout that shards cleanly — groups
over the `data` axes, experts over `model` — so SPMD lowers dispatch/combine
into all-to-alls over the GMI communicator axes.

Capacity-dropping (GShard-style, capacity_factor>=1.0) keeps shapes static;
dropped tokens pass through the residual only.  Router runs in f32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init

Params = Dict[str, Any]


def moe_init(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * 0.02).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(jnp.bfloat16),
        "wo": (jax.random.normal(ks[2], (e, f, d)) * s_out).astype(jnp.bfloat16),
    }
    if cfg.mlp_style == "swiglu":
        p["wg"] = (jax.random.normal(ks[3], (e, d, f)) * s_in).astype(jnp.bfloat16)
    if cfg.n_shared_experts:
        fs = cfg.d_ff * cfg.n_shared_experts
        p["shared_wi"] = dense_init(ks[4], d, fs)
        p["shared_wo"] = dense_init(jax.random.fold_in(ks[4], 1), fs, d)
        if cfg.mlp_style == "swiglu":
            p["shared_wg"] = dense_init(jax.random.fold_in(ks[4], 2), d, fs)
    return p


def _capacity(cfg, tokens_per_group: int) -> int:
    c = int(math.ceil(cfg.top_k * tokens_per_group * cfg.capacity_factor
                      / cfg.n_experts))
    return max(c, 1)


def moe_ffn(x: jax.Array, p: Params, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (y, aux_loss). Groups = batch rows."""
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, s)
    act = act_fn(cfg.act)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)  # renormalize top-k

    # load-balance aux loss (Switch): e * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[..., 0], e)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # -- rank each (token, choice) within its expert (per group) ------------
    flat_ids = expert_ids.reshape(bsz, s * k)  # (B, S*k) in routing order
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (B,S*k,E)
    rank_in_expert = jnp.cumsum(onehot, axis=1) - onehot  # exclusive
    pos = jnp.sum(rank_in_expert * onehot, axis=-1)  # (B, S*k)
    keep = pos < cap
    slot = jnp.where(keep, flat_ids * cap + pos, e * cap)  # overflow slot

    # -- dispatch: gather tokens into (B, E*cap(+1), D) ----------------------
    # NB: index arrays stay rank-2 (no (B,S*k,D) broadcast index tensors —
    # those materialize in the backward pass and dwarf the activations), and
    # every (B, S*k, D) intermediate is pinned batch-sharded: the gather /
    # scatter transposes otherwise replicate the batch under SPMD
    from repro.models.shard_hints import hint

    token_idx = jnp.repeat(jnp.arange(s), k)  # (S*k,)
    src = hint(jnp.take(x, token_idx, axis=1, mode="clip"), "btd")  # (B,S*k,D)
    # per-row scatter vmapped over batch: lowers to a scatter with operand
    # batching dims, which SPMD partitions trivially over `data` (a scatter
    # whose batch coord is a *scattered* dim would all-gather the updates)
    buf = jax.vmap(
        lambda sl, sr: jnp.zeros((e * cap + 1, d), x.dtype).at[sl].set(
            sr, mode="drop"))(slot, src)
    buf = hint(buf, "btd")
    xe = buf[:, : e * cap].reshape(bsz, e, cap, d)
    xe = hint(xe, "moe")  # dispatch boundary: E -> model axis (all-to-all)

    # -- expert FFN (E sharded over `model`): all-to-all boundary ------------
    from repro.models.shard_hints import fsdp_int8_gather
    wi = fsdp_int8_gather(p["wi"])  # no-op unless int8_gather hints on
    wo = fsdp_int8_gather(p["wo"])
    hi = hint(jnp.einsum("becd,edf->becf", xe, wi), "moe")
    if cfg.mlp_style == "swiglu":
        wg = fsdp_int8_gather(p["wg"])
        hi = act(hint(jnp.einsum("becd,edf->becf", xe, wg), "moe")) * hi
    else:
        hi = act(hi)
    ye = hint(jnp.einsum("becf,efd->becd", hi, wo), "moe")

    # -- combine: gather back + weight by gates ------------------------------
    ye_flat = hint(ye.reshape(bsz, e * cap, d), "btd")
    ye_flat = jnp.concatenate(
        [ye_flat, jnp.zeros((bsz, 1, d), ye.dtype)], axis=1)
    back = jax.vmap(
        lambda yb, sb: jnp.take(yb, sb, axis=0, mode="clip"))(ye_flat, slot)
    back = hint(back, "btd")
    w = (gate_vals.reshape(bsz, s * k) * keep).astype(x.dtype)
    y = jnp.sum((back * w[..., None]).reshape(bsz, s, k, d), axis=2)

    if cfg.n_shared_experts:
        hs = jnp.einsum("bsd,df->bsf", x, p["shared_wi"])
        if cfg.mlp_style == "swiglu":
            hs = act(jnp.einsum("bsd,df->bsf", x, p["shared_wg"])) * hs
        else:
            hs = act(hs)
        # serve_exact gathers / serve_psum keeps f-sharded before the
        # shared_wo reduction, mirroring the dense mlp (no-ops elsewhere)
        hs = hint(hint(hs, "gather"), "psum")
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_wo"])

    return y, aux.astype(jnp.float32)
