"""Generic decoder backbone covering all 10 assigned architectures.

Layers are grouped into *periods* (the repeating block pattern: 1 for dense,
2 for interleaved MoE, 3 for Griffin's rglru/rglru/attn, 8 for xLSTM's 7:1)
and scanned with stacked parameters, so HLO size is O(period), not O(L) —
essential to keep 512-device SPMD compiles tractable (DESIGN.md §3).  The
remainder layers (e.g. recurrentgemma's 26 = 8*3 + 2) are unrolled as a tail.

The same block code serves train (no state), prefill (state in/out), and
decode (single-token state update), switched by the cache pytree.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import recurrent as rec_mod
from repro.models.layers import (
    COMPUTE_DTYPE, cross_entropy_chunked, embed, embed_init, lm_head,
    mlp, mlp_init, norm, norm_init,
)
from repro.models.moe import moe_ffn, moe_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


def period_length(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid" and cfg.block_pattern:
        return len(cfg.block_pattern)
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.slstm_every
    if cfg.n_experts and cfg.moe_every > 1:
        return cfg.moe_every
    return 1


def layer_plan(cfg: ModelConfig) -> Tuple[int, int, List[str]]:
    """(n_repeat, tail_len, kinds-of-one-period)."""
    p = period_length(cfg)
    kinds = [cfg.block_kind(i) for i in range(p)]
    return cfg.n_layers // p, cfg.n_layers % p, kinds


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, pos_in_period: int) -> Params:
    kind = cfg.block_kind(pos_in_period)
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": norm_init(cfg)}
    if kind == "attn":
        p["mix"] = attn_mod.attn_init(ks[0], cfg)
    elif kind == "rglru":
        p["mix"] = rec_mod.rglru_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mix"] = rec_mod.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["mix"] = rec_mod.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cfg.family != "ssm":
        p["norm2"] = norm_init(cfg)
        if cfg.is_moe_layer(pos_in_period):
            p["ffn"] = moe_init(ks[1], cfg)
        else:
            p["ffn"] = mlp_init(ks[1], cfg)
    return p


def block_apply(cfg: ModelConfig, pos_in_period: int, p: Params, h: jax.Array,
                positions: jax.Array, segment_ids, state,
                pos_contiguous: bool = False, page_table=None, active=None):
    """Returns (h, new_state, aux_loss)."""
    kind = cfg.block_kind(pos_in_period)
    z = norm(h, p["norm1"], cfg)
    if kind == "attn":
        y, new_state = attn_mod.attention(z, p["mix"], cfg, positions,
                                          segment_ids, cache=state,
                                          pos_contiguous=pos_contiguous,
                                          page_table=page_table,
                                          active=active)
    else:
        # pads (pos sentinel 2^30 or segment -1) must not touch the state
        valid = positions < 2**29
        if segment_ids is not None:
            valid &= segment_ids >= 0
        if kind == "rglru":
            y, new_state = rec_mod.rglru_block(z, p["mix"], cfg, state, valid)
        elif kind == "mlstm":
            y, new_state = rec_mod.mlstm_block(z, p["mix"], cfg, state, valid)
        else:  # slstm
            y, new_state = rec_mod.slstm_block(z, p["mix"], cfg, state, valid)
    h = h + y
    aux = jnp.float32(0.0)
    if "ffn" in p:
        z = norm(h, p["norm2"], cfg)
        if cfg.is_moe_layer(pos_in_period):
            y, aux = moe_ffn(z, p["ffn"], cfg)
        else:
            y = mlp(z, p["ffn"], cfg)
        h = h + y
    return h, new_state, aux


def block_init_state(cfg: ModelConfig, pos_in_period: int, batch: int,
                     seq_len: int):
    kind = cfg.block_kind(pos_in_period)
    if kind == "attn":
        return attn_mod.init_attn_cache(cfg, batch, seq_len)
    if kind == "rglru":
        return rec_mod.init_rglru_state(cfg, batch)
    if kind == "mlstm":
        return rec_mod.init_mlstm_state(cfg, batch)
    return rec_mod.init_slstm_state(cfg, batch)


# ---------------------------------------------------------------------------
# cache pytree utilities (slot-table serving)
# ---------------------------------------------------------------------------


def cache_map(fn, *trees):
    """Map ``fn(batch_axis, *leaves)`` over cache pytrees.

    Cache trees are ``{"scan": ..., "tail": ..., "pos": ...}``; leaves under
    "scan" carry a leading stacked-period dim, so their batch axis is 1,
    everything else has batch at axis 0.  Used by the serving engine's
    per-slot insert/select operations, which must address the batch dim.
    """

    def go(axis, *subs):
        if isinstance(subs[0], dict):
            return {k: go(axis, *[s[k] for s in subs]) for k in subs[0]}
        return fn(axis, *subs)

    return {k: go(1 if k == "scan" else 0, *[t[k] for t in trees])
            for k in trees[0]}


def _batch_broadcast(mask: jax.Array, axis: int, ndim: int):
    """(B,) mask -> shape broadcastable against a leaf with batch at `axis`."""
    shape = [1] * ndim
    shape[axis] = mask.shape[0]
    return mask.reshape(shape)


def cache_is_quantized(tree) -> bool:
    """Does this (sub)tree hold int8 KV arenas (k_scale planes present)?"""
    if isinstance(tree, dict):
        if "k_scale" in tree:
            return True
        return any(cache_is_quantized(v) for v in tree.values())
    return False


def quantize_kv_tree(tree):
    """Convert a dense bf16 cache pytree's attention leaves
    ({"k","v","kpos"}) to the quantized arena leaf layout
    ({"k": int8, "v": int8, "k_scale", "v_scale", "kpos"}).

    Quantization is per cache row per kv head over head_dim
    (core/quant.kv_quantize), so it is layout-agnostic: the same rule the
    decode scatter applies token-by-token, applied here to a whole prefill
    bucket at once — which is what keeps prefix-hit suffix ingest
    bit-identical to cold prefill under int8 KV too.
    """
    from repro.core.quant import kv_quantize

    def go(t):
        if isinstance(t, dict):
            if "k" in t and "kpos" in t and "k_scale" not in t:
                kq, ks = kv_quantize(t["k"])
                vq, vs = kv_quantize(t["v"])
                return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                        "kpos": t["kpos"]}
            return {k: go(v) for k, v in t.items()}
        return t

    return go(tree)


def paged_cache_map(fn, *trees):
    """Map ``fn(page_axis, leaf_name, *leaves)`` over the scan/tail arena
    leaves of paged cache pytrees.

    Arena leaves ((P, ps, ...) under "tail", (n_rep, P, ps, ...) under
    "scan") have a *page* axis where dense slot caches have a batch axis;
    top-level "pos"/"pt" are per-lane and handled by the caller.
    """

    def go(axis, name, *subs):
        if isinstance(subs[0], dict):
            return {k: go(axis, k, *[s[k] for s in subs]) for k in subs[0]}
        return fn(axis, name, *subs)

    return {k: (go(1 if k == "scan" else 0, k, *[t[k] for t in trees])
                if k in ("scan", "tail") else trees[0][k])
            for k in trees[0]}


def spec_acceptance(ins, tgt, active, remaining, eos_id, pad_token,
                    forced, forced_len, forced_ptr):
    """Vectorized acceptance state machine for one speculative block.

    ins: (B, T) the block's input tokens (T = k+1; column 0 is the lane's
    current input, later columns are forced-queue tokens or draft
    proposals); tgt: (B, T) the target's greedy argmax at each position.
    Mirrors `decode_steps`' forced-queue semantics step for step: step j's
    output is swallowed while ``forced_ptr + j < forced_len``; a *drafted*
    input (one the forced queue didn't cover) is only consumed if it equals
    the target's argmax at the previous step; the first divergence emits
    the target's own argmax — which is already ``tgt[:, j-1]``, the token
    whose emission preceded the divergence — so rejection costs nothing and
    the emitted stream is bit-identical to non-speculative greedy decode.

    Returns (emit (T, B) with -1 holes, cur, alive, remaining, forced_ptr,
    n_consumed) — `n_consumed` is how many block positions the equivalent
    sequential execution would have run, i.e. the position-counter advance
    for both the target and draft caches.
    """
    b, t = ins.shape
    lane = jnp.arange(b)
    fcap = forced.shape[1]
    valid = active   # step 0's input is the lane's own cur: always matched
    alive = active
    rem = remaining
    n_consumed = jnp.zeros((b,), jnp.int32)
    emits = []
    for j in range(t):
        if j > 0:
            drafted = (forced_ptr + j - 1) >= forced_len
            matched = ~drafted | (ins[:, j] == tgt[:, j - 1])
            valid = valid & matched & alive
        n_consumed = n_consumed + valid.astype(jnp.int32)
        swallowed = (forced_ptr + j) < forced_len
        emitting = valid & ~swallowed
        emits.append(jnp.where(emitting, tgt[:, j], -1))
        rem = jnp.where(emitting, rem - 1, rem)
        exited = emitting & ((tgt[:, j] == eos_id) | (rem <= 0))
        alive = alive & ~exited
    # next input for the sequential-equivalent state: the first unconsumed
    # step's input — a still-pending forced token, or the target argmax of
    # the last consumed step (the correction token on divergence, the bonus
    # token on full acceptance; both were just emitted)
    idx = forced_ptr + n_consumed - 1
    from_forced = idx < forced_len
    nxt = jnp.where(
        from_forced,
        forced[lane, jnp.clip(idx, 0, fcap - 1)],
        tgt[lane, jnp.clip(n_consumed - 1, 0, t - 1)]).astype(jnp.int32)
    cur = jnp.where(alive, nxt, pad_token).astype(jnp.int32)
    fptr = forced_ptr + jnp.minimum(
        jnp.maximum(forced_len - forced_ptr, 0), n_consumed)
    return (jnp.stack(emits, axis=0), cur, alive, rem, fptr.astype(jnp.int32),
            n_consumed)


def greedy_token_update(logits, cur, active, remaining, eos_id, pad_token):
    """One step of the fused decode loop's token state machine (no forced
    queue): greedy argmax, -1 emission for masked lanes, EOS/budget lane
    exit, pad feedback.  Shared verbatim by `Model.decode_steps` and the
    serving executor's pipelined decode program, so the two are
    bit-identical by construction.  Returns (emit, cur, active, remaining).
    """
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    emit = jnp.where(active, nxt, -1)
    remaining = jnp.where(active, remaining - 1, remaining)
    still = active & (nxt != eos_id) & (remaining > 0)
    # finished/free lanes feed the pad token, never a stale sample
    cur = jnp.where(still, nxt, pad_token).astype(jnp.int32)
    return emit, cur, still, remaining


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    n_rep, tail, kinds = layer_plan(cfg)
    keys = jax.random.split(key, n_rep + tail + 2)

    def one_period(k):
        ks = jax.random.split(k, len(kinds))
        return {f"b{i}": block_init(ks[i], cfg, i) for i in range(len(kinds))}

    periods = [one_period(keys[i]) for i in range(n_rep)]
    scan_params = jax.tree.map(lambda *xs: jnp.stack(xs), *periods) if n_rep \
        else {}
    tail_params = {
        str(t): block_init(keys[n_rep + t], cfg, t) for t in range(tail)
    }
    return {
        "embed": embed_init(keys[-2], cfg),
        "scan": scan_params,
        "tail": tail_params,
        "final_norm": norm_init(cfg),
    }


class Model:
    """Thin functional wrapper binding a ModelConfig to apply functions."""

    def __init__(self, cfg: ModelConfig, remat: bool = True):
        self.cfg = cfg
        self.remat = remat

    # -- core --------------------------------------------------------------

    def backbone(self, params: Params, h: jax.Array, positions: jax.Array,
                 segment_ids=None, caches=None, pos_contiguous: bool = False,
                 page_table=None, active=None):
        """h: (B,S,D) embeddings -> (h_final, new_caches, aux).

        pos_contiguous: positions are a plain broadcast arange (no pad
        sentinels) — lets long-prefill attention take the Pallas fused path.
        page_table/active: paged-KV decode (models/attention.py) — the
        table is shared by every layer, so it rides alongside positions
        instead of being stacked into the per-layer cache pytree.
        """
        cfg = self.cfg
        n_rep, tail, kinds = layer_plan(cfg)
        np_ = len(kinds)

        def period_fn(h, period_params, period_caches):
            new_caches = {}
            aux = jnp.float32(0.0)
            for i in range(np_):
                st = None if period_caches is None else period_caches[f"b{i}"]
                h, ns, a = block_apply(cfg, i, period_params[f"b{i}"], h,
                                       positions, segment_ids, st,
                                       pos_contiguous=pos_contiguous,
                                       page_table=page_table, active=active)
                if period_caches is not None:
                    new_caches[f"b{i}"] = ns
                aux = aux + a
            return h, new_caches, aux

        pf = period_fn
        if self.remat and caches is None:
            pf = jax.checkpoint(
                period_fn,
                policy=jax.checkpoint_policies.nothing_saveable,
            )

        if n_rep:
            def scan_body(carry, xs):
                h, aux_acc = carry
                pp = xs[0] if caches is not None else xs
                pc = xs[1] if caches is not None else None
                h, ncache, aux = pf(h, pp, pc)
                return (h, aux_acc + aux), (ncache if caches is not None
                                            else None)

            xs = (params["scan"], caches["scan"]) if caches is not None \
                else params["scan"]
            (h, aux), new_scan_caches = jax.lax.scan(
                scan_body, (h, jnp.float32(0.0)), xs)
        else:
            aux = jnp.float32(0.0)
            new_scan_caches = None

        new_tail = {}
        for t in range(tail):
            st = None if caches is None else caches["tail"][str(t)]
            h, ns, a = block_apply(cfg, t, params["tail"][str(t)], h,
                                   positions, segment_ids, st,
                                   pos_contiguous=pos_contiguous,
                                   page_table=page_table, active=active)
            if caches is not None:
                new_tail[str(t)] = ns
            aux = aux + a

        h = norm(h, params["final_norm"], cfg)
        new_caches = (None if caches is None else
                      {"scan": new_scan_caches, "tail": new_tail})
        return h, new_caches, aux

    def embed_inputs(self, params, tokens=None, embeds=None):
        if embeds is not None:
            return embeds.astype(COMPUTE_DTYPE)
        return embed(tokens, params["embed"])

    # -- entry points --------------------------------------------------------

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch: tokens|embeds, labels, positions?, segment_ids?"""
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        x = self.embed_inputs(params, tokens, embeds)
        b, s = x.shape[:2]
        positions = batch.get("positions")
        contiguous = positions is None
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h, _, aux = self.backbone(params, x, positions,
                                  batch.get("segment_ids"),
                                  pos_contiguous=contiguous)
        ce = cross_entropy_chunked(h, batch["labels"], params["embed"])
        return ce + 0.01 * aux

    def forward_logits(self, params, tokens=None, embeds=None, positions=None):
        x = self.embed_inputs(params, tokens, embeds)
        b, s = x.shape[:2]
        contiguous = positions is None
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h, _, _ = self.backbone(params, x, positions,
                                pos_contiguous=contiguous)
        return lm_head(h, params["embed"])

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        n_rep, tail, kinds = layer_plan(cfg)

        def one_period():
            return {f"b{i}": block_init_state(cfg, i, batch, seq_len)
                    for i in range(len(kinds))}

        scan_caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), one_period()
        ) if n_rep else {}
        tail_caches = {str(t): block_init_state(cfg, t, batch, seq_len)
                       for t in range(tail)}
        return {"scan": scan_caches, "tail": tail_caches,
                "pos": jnp.zeros((batch,), jnp.int32)}

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int,
                         max_pages: int, kv_dtype: str = "bf16"):
        """Paged serving cache: global KV page arenas + per-lane tables.

        Tree: {"scan"/"tail": per-layer {"k","v","kpos"} arenas with no
        batch axis (models/attention.init_paged_attn_cache), "pos": (B,)
        position counters, "pt": (B, max_pages) int32 page tables (0 = the
        allocator's reserved trash page)}.  Only all-attention configs
        qualify — recurrent state has no paged analogue, and ring-buffer
        (windowed) caches stay on the dense slot path.

        kv_dtype="int8" stores quantized arenas (int8 k/v + f32
        `k_scale`/`v_scale` planes): ~half the HBM per cache row, so an
        equal byte budget holds ~2x the pages (docs/perf.md §int8 pages).
        """
        cfg = self.cfg
        n_rep, tail, kinds = layer_plan(cfg)
        bad = [k for k in kinds if k != "attn"]
        assert not bad, f"paged KV needs an all-attention model, got {bad}"
        assert kv_dtype in ("bf16", "int8"), kv_dtype
        quant = kv_dtype == "int8"

        def one_period():
            return {f"b{i}": attn_mod.init_paged_attn_cache(
                cfg, num_pages, page_size, quantized=quant)
                for i in range(len(kinds))}

        scan_caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), one_period()
        ) if n_rep else {}
        tail_caches = {str(t): attn_mod.init_paged_attn_cache(
            cfg, num_pages, page_size, quantized=quant)
            for t in range(tail)}
        return {"scan": scan_caches, "tail": tail_caches,
                "pos": jnp.zeros((batch,), jnp.int32),
                "pt": jnp.zeros((batch, max_pages), jnp.int32)}

    def prefill(self, params, caches, tokens=None, embeds=None,
                positions=None, last_idx=None):
        """Fill caches from a (left-aligned) prompt.

        last_idx: (B,) index of each request's final prompt token (for
        padded batches of unequal lengths); defaults to S-1.
        Returns (logits at last_idx, caches).
        """
        x = self.embed_inputs(params, tokens, embeds)
        b, s = x.shape[:2]
        contiguous = positions is None
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if last_idx is None:
            last_idx = jnp.full((b,), s - 1, jnp.int32)
        sub = {"scan": caches["scan"], "tail": caches["tail"]}
        h, sub, _ = self.backbone(params, x, positions, caches=sub,
                                  pos_contiguous=contiguous)
        bidx = jnp.arange(b)
        last_pos = positions[bidx, last_idx].astype(jnp.int32)
        caches = dict(sub, pos=last_pos + 1)
        h_last = h[bidx, last_idx][:, None]
        return lm_head(h_last, params["embed"])[:, 0], caches

    def decode_step(self, params, caches, token: jax.Array,
                    active: Optional[jax.Array] = None):
        """token: (B,) int32 (or (B,D) embeds for stub frontends).

        active: optional (B,) bool slot mask (continuous batching).  Rows
        with ``active=False`` are computed (the batch shape is static) but
        their cache entries and position counters are left untouched, so a
        free/finished slot cannot corrupt its state between an occupant
        finishing and the next admission overwriting the slot.
        """
        if token.ndim == 1:
            x = self.embed_inputs(params, tokens=token[:, None])
        else:
            x = token[:, None, :].astype(COMPUTE_DTYPE)
        positions = caches["pos"][:, None]
        sub = {"scan": caches["scan"], "tail": caches["tail"]}
        if "pt" in caches:
            # paged: writes are already active-gated inside attention (an
            # arena has no batch axis for cache_map's where-masking), so
            # only the per-lane position counters need the mask here
            h, sub, _ = self.backbone(params, x, positions, caches=sub,
                                      page_table=caches["pt"], active=active)
            pos = caches["pos"] + 1 if active is None else jnp.where(
                active, caches["pos"] + 1, caches["pos"])
            return (lm_head(h[:, -1:], params["embed"])[:, 0],
                    dict(sub, pos=pos, pt=caches["pt"]))
        h, sub, _ = self.backbone(params, x, positions, caches=sub)
        new_caches = dict(sub, pos=caches["pos"] + 1)
        if active is not None:
            new_caches = cache_map(
                lambda ax, new, old: jnp.where(
                    _batch_broadcast(active, ax, new.ndim), new, old),
                new_caches, caches)
        return lm_head(h[:, -1:], params["embed"])[:, 0], new_caches

    def decode_steps(self, params, caches, token: jax.Array,
                     active: jax.Array, n: int,
                     eos_id: Optional[jax.Array] = None,
                     budget: Optional[jax.Array] = None,
                     pad_token: int = 0,
                     forced: Optional[jax.Array] = None,
                     forced_len: Optional[jax.Array] = None,
                     forced_ptr: Optional[jax.Array] = None):
        """n fused greedy decode steps as one on-device ``lax.scan``.

        The serving fast path: instead of one jit dispatch + one (B, V)
        logits fetch + a host argmax per generated token, the whole hot loop
        (decode_step -> greedy argmax -> feed back) runs on the accelerator
        and the host fetches a single (n, B) int32 token block per dispatch.

        token: (B,) int32 current input tokens; active: (B,) bool slot mask;
        eos_id: optional (B,) int32 per-slot EOS (-1 = never); budget:
        optional (B,) int32 tokens each slot may still emit.  A slot
        early-exits on device — its ``active`` lane drops after it emits EOS
        or exhausts its budget, and from then on it emits -1 and (like any
        inactive slot) leaves its cache rows and position counter untouched,
        so the token streams are bit-identical to n chained ``decode_step``
        calls reconciled on the host.

        forced/forced_len/forced_ptr (all or none): per-lane queues of
        *forced* input tokens — the prefix-cache hit path's prompt-suffix
        ingest.  While ``forced_ptr[b] < forced_len[b]`` the lane feeds
        ``forced[b, forced_ptr[b]]`` as the next input instead of its own
        argmax, emits -1 (nothing generated yet), and leaves its budget and
        EOS state untouched; the step that consumes the lane's last pending
        input emits the first generated token.  This is chunked prefill
        riding the decode loop: the forced tokens' KV lands at the right
        positions and the resulting stream is bit-identical to a cold
        prefill of the full prompt.

        Returns (tokens (n, B) int32 with -1 for inactive lanes, next token
        (B,), active (B,), remaining budget (B,), caches) — with a forced
        queue, the advanced forced_ptr (B,) is inserted before caches.
        """
        b = token.shape[0]
        if eos_id is None:
            eos_id = jnp.full((b,), -1, jnp.int32)
        if budget is None:
            budget = jnp.full((b,), 2 ** 30, jnp.int32)

        if forced is None:

            def step(carry, _):
                cur, act, rem, caches = carry
                logits, caches = self.decode_step(params, caches, cur,
                                                  active=act)
                emit, cur, still, rem = greedy_token_update(
                    logits, cur, act, rem, eos_id, pad_token)
                return (cur, still, rem, caches), emit

            (cur, act, rem, caches), toks = jax.lax.scan(
                step, (token.astype(jnp.int32), active, budget, caches),
                None, length=n)
            return toks, cur, act, rem, caches

        fcap = forced.shape[1]
        lane = jnp.arange(b)

        def step(carry, _):
            cur, act, rem, fptr, caches = carry
            logits, caches = self.decode_step(params, caches, cur,
                                              active=act)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pending = fptr < forced_len  # this step's output is swallowed
            emitting = act & ~pending
            emit = jnp.where(emitting, nxt, -1)
            rem = jnp.where(emitting, rem - 1, rem)
            still = act & (pending | ((nxt != eos_id) & (rem > 0)))
            feed = jnp.where(
                pending, forced[lane, jnp.minimum(fptr, fcap - 1)], nxt)
            cur = jnp.where(still, feed, pad_token).astype(jnp.int32)
            fptr = jnp.where(act & pending, fptr + 1, fptr)
            return (cur, still, rem, fptr, caches), emit

        (cur, act, rem, fptr, caches), toks = jax.lax.scan(
            step, (token.astype(jnp.int32), active, budget,
                   forced_ptr.astype(jnp.int32), caches), None, length=n)
        return toks, cur, act, rem, fptr, caches

    def draft_steps(self, params, caches, token: jax.Array,
                    active: jax.Array, n_draft: int,
                    forced: jax.Array, forced_len: jax.Array,
                    forced_ptr: jax.Array, pad_token: int = 0):
        """Build one speculative input block on the draft model.

        Runs ``n_draft + 1`` draft decode steps: step j consumes input
        ``ins[:, j]`` — the forced-queue token when the queue still covers
        the position (so a prefix-hit lane's draft cache ingests the same
        suffix stream the target does), the previous step's draft argmax
        otherwise.  The final step exists only to ingest the last input's
        KV, so the draft cache covers every position the target will
        verify; its output is discarded.  Returns (ins (B, n_draft+1),
        caches) with the draft position counters advanced by n_draft+1 —
        the caller rewinds them to the accepted length.
        """
        b = token.shape[0]
        lane = jnp.arange(b)
        fcap = forced.shape[1]
        cur = jnp.where(active, token, pad_token).astype(jnp.int32)
        ins = [cur]
        for j in range(n_draft + 1):
            logits, caches = self.decode_step(params, caches, cur,
                                              active=active)
            if j == n_draft:
                break
            prop = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            idx = forced_ptr + j
            nxt = jnp.where(idx < forced_len,
                            forced[lane, jnp.minimum(idx, fcap - 1)], prop)
            cur = jnp.where(active, nxt, pad_token).astype(jnp.int32)
            ins.append(cur)
        return jnp.stack(ins, axis=1), caches

    def verify_block(self, params, caches, tokens: jax.Array,
                     active: Optional[jax.Array] = None):
        """Batched target pass over T contiguous speculative inputs.

        tokens: (B, T) — position ``caches['pos'][b] + j`` for column j.
        One backbone call with Sq = T rides the paged multi-query verify
        branch (models/attention.py): every row's logits are bitwise
        identical to what T chained single-step `decode_step` calls with
        the same inputs would produce, which is the whole lossless-greedy
        argument.  Returns (logits (B, T, V), caches) with the position
        counters untouched — the caller advances them by the accepted
        length only.
        """
        assert "pt" in caches, "verify_block requires a paged cache"
        b, t = tokens.shape
        x = self.embed_inputs(params, tokens=tokens)
        positions = (caches["pos"][:, None]
                     + jnp.arange(t, dtype=jnp.int32)[None, :])
        sub = {"scan": caches["scan"], "tail": caches["tail"]}
        h, sub, _ = self.backbone(params, x, positions, caches=sub,
                                  page_table=caches["pt"], active=active)
        logits = lm_head(h, params["embed"])
        return logits, dict(sub, pos=caches["pos"], pt=caches["pt"])

    def spec_decode_step(self, params, caches, token: jax.Array,
                         active: jax.Array, n_draft: int,
                         draft_model: "Model", draft_params, draft_caches,
                         eos_id: Optional[jax.Array] = None,
                         budget: Optional[jax.Array] = None,
                         pad_token: int = 0,
                         forced: Optional[jax.Array] = None,
                         forced_len: Optional[jax.Array] = None,
                         forced_ptr: Optional[jax.Array] = None):
        """One fused speculative block: draft scan + batched target verify
        + acceptance ingest, emitting up to ``n_draft + 1`` tokens per lane
        per dispatch while staying bit-identical to `decode_steps`.

        Rejection rollback is a position-counter rewind on both caches:
        rejected rows sit at kpos beyond every future query position until
        the sequential stream overwrites them (write-then-attend plus the
        causal mask make them unreachable — docs/serving.md §speculative
        decoding).  Returns (toks (n_draft+1, B), cur, active, remaining,
        forced_ptr, caches, draft_caches, n_consumed).
        """
        b = token.shape[0]
        if eos_id is None:
            eos_id = jnp.full((b,), -1, jnp.int32)
        if budget is None:
            budget = jnp.full((b,), 2 ** 30, jnp.int32)
        if forced is None:
            forced = jnp.zeros((b, 1), jnp.int32)
            forced_len = jnp.zeros((b,), jnp.int32)
            forced_ptr = jnp.zeros((b,), jnp.int32)
        ins, draft_caches = draft_model.draft_steps(
            draft_params, draft_caches, token, active, n_draft,
            forced, forced_len, forced_ptr, pad_token)
        logits, caches = self.verify_block(params, caches, ins,
                                           active=active)
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks, cur, alive, rem, fptr, v = spec_acceptance(
            ins, tgt, active, budget, eos_id, pad_token,
            forced, forced_len, forced_ptr)
        caches = dict(caches, pos=jnp.where(
            active, caches["pos"] + v, caches["pos"]))
        draft_caches = dict(draft_caches, pos=jnp.where(
            active, draft_caches["pos"] - (n_draft + 1) + v,
            draft_caches["pos"]))
        return toks, cur, alive, rem, fptr, caches, draft_caches, v

    def insert_prefill_cache(self, big, small, slot: jax.Array):
        """Write batch-1 prefill caches `small` into row `slot` of the
        persistent slot table `big` (prefill-on-admission).

        Leaf shapes must match except the batch dim (1 vs max_batch) and,
        optionally, the KV slot dim, which may be shorter in `small` when
        prefill ran with a smaller bucket cache; the gap is refilled with
        zeros (k/v) or the never-written position sentinel (kpos), so stale
        entries from the slot's previous occupant can never be attended to.
        """
        slot = jnp.asarray(slot, jnp.int32)

        def leaf(axis, b, s):
            s = s.astype(b.dtype)
            tgt = b.shape[:axis] + (1,) + b.shape[axis + 1:]
            if s.shape != tgt:
                fill = 2 ** 30 if b.dtype == jnp.int32 else 0  # kpos sentinel
                pad = [(0, t - d) for t, d in zip(tgt, s.shape)]
                s = jnp.pad(s, pad, constant_values=fill)
            return jax.lax.dynamic_update_slice_in_dim(b, s, slot, axis=axis)

        return cache_map(leaf, big, small)

    def admit_lane_cache(self, big, slot: jax.Array, pt_row: jax.Array,
                         pos0: jax.Array, reset_pages: jax.Array,
                         small=None, write_pages=None):
        """Prepare one lane of a *paged* cache `big` for a new occupant.

        reset_pages: (R,) int32 — arena pages whose `kpos` return to the
        never-written sentinel before use (pages are recycled, so a new
        owner must be unable to attend to the previous occupant's keys;
        pad with the trash page 0, which is idempotently sentinel).
        small/write_pages: optional batch-1 bucket cache from a dense
        prefill plus the (W,) pages that receive it — positions
        [0, W*page_size) land at write_pages in prompt order.  The lane's
        page-table row becomes `pt_row` and its position counter `pos0`
        (the prompt length; a prefix-cache hit passes hit_len and no
        `small` — the suffix arrives through the decode loop's forced
        queue instead).  A quantized arena (`kv_dtype="int8"`) quantizes
        the bucket cache on the way in, per cache row, so its leaves match
        the arena's int8 + scale layout.
        """
        slot = jnp.asarray(slot, jnp.int32)
        if small is not None and cache_is_quantized(big):
            small = quantize_kv_tree(small)

        def leaf(page_axis, name, b, s):
            ps = b.shape[page_axis + 1]
            if name == "kpos":
                sent = jnp.full((reset_pages.shape[0], ps) if page_axis == 0
                                else (b.shape[0], reset_pages.shape[0], ps),
                                2 ** 30, b.dtype)
                b = (b.at[reset_pages].set(sent) if page_axis == 0
                     else b.at[:, reset_pages].set(sent))
            if s is None:
                return b
            s = jnp.squeeze(s, axis=page_axis).astype(b.dtype)
            n_wp = write_pages.shape[0]
            need, got = n_wp * ps, s.shape[page_axis]
            if got < need:
                fill = 2 ** 30 if name == "kpos" else 0
                pad = [(0, 0)] * s.ndim
                pad[page_axis] = (0, need - got)
                s = jnp.pad(s, pad, constant_values=fill)
            elif got > need:  # lane owns fewer pages than the bucket spans
                s = jax.lax.slice_in_dim(s, 0, need, axis=page_axis)
            s = s.reshape(s.shape[:page_axis] + (n_wp, ps)
                          + s.shape[page_axis + 1:])
            return (b.at[write_pages].set(s) if page_axis == 0
                    else b.at[:, write_pages].set(s))

        sub = {"scan": small["scan"], "tail": small["tail"]} \
            if small is not None else None
        out = paged_cache_map(
            lambda ax, name, bb: leaf(ax, name, bb, None), big) \
            if sub is None else paged_cache_map(
                lambda ax, name, bb, ss: leaf(ax, name, bb, ss), big,
                {"scan": sub["scan"], "tail": sub["tail"],
                 "pos": big["pos"], "pt": big["pt"]})
        out["pos"] = big["pos"].at[slot].set(jnp.asarray(pos0, jnp.int32))
        out["pt"] = big["pt"].at[slot].set(
            jnp.asarray(pt_row, jnp.int32))
        return out


def make_model(cfg: ModelConfig, remat: bool = True) -> Model:
    return Model(cfg, remat=remat)
