"""I-BERT encoder — the paper's proof-of-concept model (§7), integer-only.

Mirrors the paper's Fig. 10 six-layer encoder decomposition:
  L0 Linear(QKV)+Quant  L1 Attention Dot-Product  L2 i-Softmax
  L3 Softmax MatMul+Quant (+output Linear+Quant)  L4 Add & i-LayerNorm
  L5 Linear+i-GELU, Linear+Quant                  L6 Add & i-LayerNorm

Activation scales are *calibrated* offline (float forward pass recording
per-site amax), exactly as I-BERT does, so the runtime integer path uses
static scales and the Pallas int8 GEMM can fuse its requant epilogue.

The float forward here is simultaneously: the calibration pass, the accuracy
oracle (the paper validates bit-parity against the software I-BERT), and the
FP baseline for the paper-table benchmarks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import ibert_ops as iops
from repro.core.quant import QTensor, quantize, requantize
from repro.kernels import ops as kops

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# float parameters & forward (calibration + oracle)
# ---------------------------------------------------------------------------


def init_ibert_params(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f, v, m = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.max_seq_len
    ks = iter(jax.random.split(key, 8 + 10 * cfg.n_layers))

    def lin(d_in, d_out):
        return {
            "w": jax.random.normal(next(ks), (d_in, d_out), jnp.float32)
            / math.sqrt(d_in),
            "b": jnp.zeros((d_out,), jnp.float32),
        }

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "wq": lin(d, d), "wk": lin(d, d), "wv": lin(d, d), "wo": lin(d, d),
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "w1": lin(d, f), "w2": lin(f, d),
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        })
    return {
        "tok": jax.random.normal(next(ks), (v, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(next(ks), (m, d), jnp.float32) * 0.02,
        "emb_ln": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "layers": {str(i): l for i, l in enumerate(layers)},
    }


def _f_ln(x, p):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-12) * p["g"] + p["b"]


def ibert_float_forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
                        mask: Optional[jax.Array] = None,
                        record: Optional[Dict[str, jax.Array]] = None):
    """Float oracle. `record` (if a dict) collects per-site amax for calibration."""

    def rec(name, x):
        if record is not None:
            record[name] = jnp.max(jnp.abs(x))
        return x

    b, s = tokens.shape
    h = params["tok"][tokens] + params["pos"][:s][None]
    h = _f_ln(h, params["emb_ln"])
    rec("emb", h)
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    if mask is None:
        mask = jnp.ones((b, s), bool)
    amask = mask[:, None, None, :]  # (B,1,1,S)

    for i in range(cfg.n_layers):
        lp = params["layers"][str(i)]
        q = rec(f"L{i}.q", h @ lp["wq"]["w"] + lp["wq"]["b"])
        k = rec(f"L{i}.k", h @ lp["wk"]["w"] + lp["wk"]["b"])
        v = rec(f"L{i}.v", h @ lp["wv"]["w"] + lp["wv"]["b"])
        qh = q.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        scores = rec(f"L{i}.scores",
                     jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(hd))
        scores = jnp.where(amask, scores, -1e30)
        probs = jax.nn.softmax(scores, -1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
        ctx = rec(f"L{i}.ctx", ctx.transpose(0, 2, 1, 3).reshape(b, s, -1))
        attn = rec(f"L{i}.attn", ctx @ lp["wo"]["w"] + lp["wo"]["b"])
        h = rec(f"L{i}.res1", h + attn)
        h = _f_ln(h, lp["ln1"])
        rec(f"L{i}.ln1", h)
        ff = rec(f"L{i}.ff1", h @ lp["w1"]["w"] + lp["w1"]["b"])
        ff = rec(f"L{i}.gelu", iops.f_gelu(ff))
        ff = rec(f"L{i}.ff2", ff @ lp["w2"]["w"] + lp["w2"]["b"])
        h = rec(f"L{i}.res2", h + ff)
        h = _f_ln(h, lp["ln2"])
        rec(f"L{i}.ln2", h)
    return h


def calibrate(params: Params, cfg: ModelConfig, tokens: jax.Array,
              mask: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    record: Dict[str, jax.Array] = {}
    ibert_float_forward(params, cfg, tokens, mask, record)
    return {k: jnp.maximum(v, 1e-3) for k, v in record.items()}


# ---------------------------------------------------------------------------
# integer parameter preparation
# ---------------------------------------------------------------------------


def _scale_of(amax) -> jax.Array:
    return jnp.asarray(amax, jnp.float32) / 127.0


def _q_lin(lin: Params, s_in: jax.Array):
    w = quantize(lin["w"])
    b_int = jnp.round(lin["b"] / (s_in * w.scale)).astype(jnp.int32)
    return {"w": w.values, "s_w": w.scale, "b": b_int}


def quantize_ibert(params: Params, cfg: ModelConfig,
                   act: Dict[str, jax.Array]) -> Params:
    """Float params + calibrated amaxes -> integer weights & static scales."""
    qp: Params = {
        "tok": params["tok"], "pos": params["pos"], "emb_ln": params["emb_ln"],
        "s_emb": _scale_of(act["emb"]), "layers": {}, "act": act,
    }
    for i in range(cfg.n_layers):
        lp = params["layers"][str(i)]
        s_emb_or_ln = _scale_of(act["emb"] if i == 0 else act[f"L{i-1}.ln2"])
        s_ln1 = _scale_of(act[f"L{i}.ln1"])
        ql = {
            "wq": _q_lin(lp["wq"], s_emb_or_ln),
            "wk": _q_lin(lp["wk"], s_emb_or_ln),
            "wv": _q_lin(lp["wv"], s_emb_or_ln),
            "wo": _q_lin(lp["wo"], _scale_of(act[f"L{i}.ctx"])),
            "w1": _q_lin(lp["w1"], s_ln1),
            "w2": _q_lin(lp["w2"], _scale_of(act[f"L{i}.gelu"])),
            "ln1": iops.layernorm_prepare(lp["ln1"]["g"], lp["ln1"]["b"]),
            "ln2": iops.layernorm_prepare(lp["ln2"]["g"], lp["ln2"]["b"]),
        }
        qp["layers"][str(i)] = ql
    return qp


# ---------------------------------------------------------------------------
# integer forward (runs on the Pallas kernels)
# ---------------------------------------------------------------------------


def _mm(x: QTensor, ql: Params, s_out, impl) -> QTensor:
    """int8 GEMM + bias + requant to s_out over collapsed leading dims."""
    lead = x.values.shape[:-1]
    a2 = x.values.reshape(-1, x.values.shape[-1])
    out = kops.int8_matmul(a2, ql["w"], x.scale, ql["s_w"],
                           s_out=s_out, bias=ql["b"], impl=impl)
    return QTensor(out.reshape(*lead, -1), jnp.asarray(s_out, jnp.float32))


def ibert_int_forward(qp: Params, cfg: ModelConfig, tokens: jax.Array,
                      mask: Optional[jax.Array] = None,
                      impl: Optional[str] = None) -> QTensor:
    """Integer-only encoder stack; returns final hidden as QTensor."""
    b, s = tokens.shape
    act = qp["act"]
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    if mask is None:
        mask = jnp.ones((b, s), bool)

    h = qp["tok"][tokens] + qp["pos"][:s][None]
    h = _f_ln(h, qp["emb_ln"])  # embedding block stays float (paper §2.3:
    # embedding is done by the input-preprocessing FPGAs, encoders are integer)
    x = quantize(h, scale=qp["s_emb"])  # int8 entry point into the encoder

    for i in range(cfg.n_layers):
        ql = qp["layers"][str(i)]
        s_q = _scale_of(act[f"L{i}.q"])
        s_k = _scale_of(act[f"L{i}.k"])
        s_v = _scale_of(act[f"L{i}.v"])
        q = _mm(x, ql["wq"], s_q, impl)
        k = _mm(x, ql["wk"], s_k, impl)
        v = _mm(x, ql["wv"], s_v, impl)

        qh = q.values.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        kh = k.values.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        # L1: attention dot-product (int8 x int8 -> int32)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh.astype(jnp.int32),
                            kh.astype(jnp.int32))
        s_scores = s_q * s_k / math.sqrt(hd)  # fold 1/sqrt(hd) into scale
        # requant scores to ACT_BITS for the i-softmax polynomial (static)
        s_sm_in = _scale_of(act[f"L{i}.scores"]) * (127.0 / iops.ACT_QMAX)
        sc = jnp.clip(jnp.round(scores.astype(jnp.float32)
                                * (s_scores / s_sm_in)),
                      -iops.ACT_QMAX, iops.ACT_QMAX).astype(jnp.int32)
        sc = jnp.where(mask[:, None, None, :], sc,
                       jnp.floor(iops._EXP_CLAMP / s_sm_in).astype(jnp.int32))
        # L2: i-softmax
        probs = kops.i_softmax(sc.reshape(-1, s), s_sm_in, impl=impl)
        probs = probs.reshape(b, nh, s, s)
        # probs at 2^-14 -> int8 at 2^-7
        p8 = (probs >> 7).astype(jnp.int8)
        s_p = jnp.float32(2.0 ** -7)
        # L3: softmax matmul (int8 probs x int8 v)
        vh = v.values.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", p8.astype(jnp.int32),
                         vh.astype(jnp.int32))
        s_ctx = _scale_of(act[f"L{i}.ctx"])
        ctx8 = requantize(ctx, s_p * s_v, s_ctx)
        ctx8 = ctx8.transpose(0, 2, 1, 3).reshape(b, s, -1)
        attn = _mm(QTensor(ctx8, s_ctx), ql["wo"],
                   _scale_of(act[f"L{i}.attn"]), impl)

        # L4: residual add (common scale) + i-LayerNorm
        s_res = _scale_of(act[f"L{i}.res1"])
        r = (requantize(x.values.astype(jnp.int32), x.scale, s_res)
             .astype(jnp.int32)
             + requantize(attn.values.astype(jnp.int32), attn.scale, s_res)
             .astype(jnp.int32))
        ln1, s_ln1v = kops.i_layernorm(r, ql["ln1"], impl=impl)
        x = QTensor(requantize(ln1, s_ln1v, _scale_of(act[f"L{i}.ln1"])),
                    _scale_of(act[f"L{i}.ln1"]))

        # L5: FFN with i-GELU
        s_ff1 = jnp.maximum(_scale_of(act[f"L{i}.ff1"]), 1e-6) \
            * (127.0 / iops.ACT_QMAX)
        a2 = x.values.reshape(-1, cfg.d_model)
        acc = kops.int8_matmul(a2, ql["w1"]["w"], x.scale, ql["w1"]["s_w"],
                               bias=ql["w1"]["b"], impl=impl)
        ff = jnp.clip(jnp.round(acc.astype(jnp.float32)
                                * (x.scale * ql["w1"]["s_w"] / s_ff1)),
                      -iops.ACT_QMAX, iops.ACT_QMAX).astype(jnp.int32)
        g = kops.i_gelu(ff, s_ff1, impl=impl)
        _, s_g = iops.i_gelu(jnp.zeros((1,), jnp.int32), s_ff1)
        g8 = requantize(g, s_g, _scale_of(act[f"L{i}.gelu"]))
        g8 = g8.reshape(b, s, cfg.d_ff)
        ff2 = _mm(QTensor(g8, _scale_of(act[f"L{i}.gelu"])), ql["w2"],
                  _scale_of(act[f"L{i}.ff2"]), impl)

        # L6: residual + i-LayerNorm
        s_res2 = _scale_of(act[f"L{i}.res2"])
        r2 = (requantize(x.values.astype(jnp.int32), x.scale, s_res2)
              .astype(jnp.int32)
              + requantize(ff2.values.astype(jnp.int32), ff2.scale, s_res2)
              .astype(jnp.int32))
        ln2, s_ln2v = kops.i_layernorm(r2, ql["ln2"], impl=impl)
        x = QTensor(requantize(ln2, s_ln2v, _scale_of(act[f"L{i}.ln2"])),
                    _scale_of(act[f"L{i}.ln2"]))
    return x
