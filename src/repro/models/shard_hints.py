"""Activation sharding hints (with_sharding_constraint injection points).

XLA's sharding propagation from weight shardings is usually right, but a
few activation boundaries (attention heads, MoE dispatch buffers, logits)
benefit from explicit constraints — without them the partitioner can pick
replicated intermediates that blow per-device temp memory at 32k sequence
lengths.  The launcher (or tests) enable hints for a mesh; model code calls
`hint(x, kind, dims)` which becomes a no-op when hints are disabled, so the
model stays mesh-agnostic.

Constraints are divisibility-aware: an axis is only assigned if it divides
the dim (uneven sharding would silently pad compute, e.g. smollm's 9 heads
on a 16-way model axis — §Perf discusses the fallback).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _cfg() -> Optional[Dict]:
    return getattr(_state, "cfg", None)


@contextmanager
def hints(mesh, dp_axes: Tuple[str, ...] = ("data",), tp_axis: str = "model",
          int8_gather: bool = False, serve_exact: bool = False,
          serve_psum: bool = False):
    """Enable activation constraints for code run inside this context.

    int8_gather=True turns FSDP weight all-gathers at `fsdp_int8_gather`
    call sites into int8 transfers (§Perf B2).

    serve_exact=True is the serving executor's bit-exact TP contract
    (cluster_builder serve mode): it (a) arms the `hint(x, "gather")`
    call sites before the replicated reduction projections, forcing the
    sharded activation to all-gather instead of leaving XLA free to pick a
    partial-dot + psum whose summation order differs from single-device
    math, and (b) publishes the mesh via `paged_shard_ctx()` so attention
    can run the paged decode kernels under shard_map with the head axis
    partitioned.

    serve_psum=True is the throughput-mode (exact=False) counterpart: it
    arms the `hint(x, "psum")` call sites instead, pinning the activation's
    last dim over tp so each shard's dot against its column-sharded
    reduction weight stays partial and XLA inserts one all-reduce — the
    Megatron form.  Mutually exclusive with serve_exact; paged_shard_ctx()
    fires under either (the paged kernels don't touch the reduction
    projections, so they are schedule-agnostic)."""
    prev = _cfg()
    _state.cfg = {
        "mesh": mesh,
        "dp": dp_axes if len(dp_axes) > 1 else dp_axes[0],
        "dp_n": _prod(mesh.shape[a] for a in dp_axes),
        "tp": tp_axis,
        "tp_n": mesh.shape[tp_axis],
        "int8_gather": int8_gather,
        "serve_exact": serve_exact,
        "serve_psum": serve_psum,
    }
    try:
        yield
    finally:
        _state.cfg = prev


def paged_shard_ctx() -> Optional[Tuple]:
    """(mesh, tp_axis, tp_n) when a serve_exact/serve_psum hints context is
    active — the signal for attention to dispatch the paged decode kernels
    under shard_map (page table replicated, head axis partitioned)."""
    c = _cfg()
    if c is None or not (c.get("serve_exact") or c.get("serve_psum")) \
            or c["tp_n"] <= 1:
        return None
    return c["mesh"], c["tp"], c["tp_n"]


def _prod(it):
    n = 1
    for x in it:
        n *= x
    return n


def hint(x: jax.Array, kind: str) -> jax.Array:
    """kind: 'btd' (batch-only, any rank) | 'bshd' (B,S,heads,hd) |
    'btf'/'btv' (B,S,model-dim-last) | 'bsni' (B,S,nh,inner: last over tp) |
    'moe' (B,experts,cap,d) | 'state' (batch-only, any rank) |
    'last' (batch + last dim over tp, any rank) |
    'gather' (serve_exact only: all-gather the tp axis before a replicated
    reduction projection) |
    'psum' (serve_psum only: pin the last dim over tp before a
    column-sharded reduction projection — partial dot + one all-reduce)."""
    c = _cfg()
    if c is None:
        return x
    dp, tp = c["dp"], c["tp"]

    def fit(dim_size, axis, n):
        return axis if dim_size % n == 0 and dim_size >= n else None

    b = fit(x.shape[0], dp, c["dp_n"])
    nd = x.ndim
    if kind == "gather":
        # the GMI gather before a replicated reduction projection
        # (serve_exact only): release the tp axis so the next dense() runs
        # replicated — bit-identical to single-device — instead of
        # partial-dot + psum.  A no-op outside serve_exact contexts, where
        # the psum form is the right (cheaper) choice for training.
        if not c.get("serve_exact"):
            return x
        spec = P(*((b,) + (None,) * (nd - 1)))
    elif kind == "psum":
        # Megatron psum-form TP (exact=False serve plans): keep the
        # activation's contraction dim sharded over tp so the dot against
        # the column-sharded reduction weight stays partial per shard and
        # XLA inserts a single all-reduce after it — the paper's
        # cross-device float accumulation.  A no-op outside serve_psum
        # contexts (training already gets this from weight propagation).
        if not c.get("serve_psum"):
            return x
        spec = P(*((b,) + (None,) * (nd - 2)
                   + (fit(x.shape[-1], tp, c["tp_n"]),)))
    elif kind in ("btd", "state"):
        spec = P(*((b,) + (None,) * (nd - 1)))
    elif kind == "bshd":
        h_ax = fit(x.shape[2], tp, c["tp_n"])
        spec = P(b, None, h_ax, None)
    elif kind == "bskv":
        # KV projections: prefer head TP; else shard head_dim so the tensor
        # lands in the KV cache's layout without a reshard (§Perf A5)
        h_ax = fit(x.shape[2], tp, c["tp_n"])
        d_ax = None if h_ax else fit(x.shape[3], tp, c["tp_n"])
        spec = P(b, None, h_ax, d_ax)
    elif kind in ("btf", "btv"):
        spec = P(b, None, fit(x.shape[2], tp, c["tp_n"]))
    elif kind in ("bsni", "last"):
        spec = P(*((b,) + (None,) * (nd - 2)
                   + (fit(x.shape[-1], tp, c["tp_n"]),)))
    elif kind == "moe":
        spec = P(b, fit(x.shape[1], tp, c["tp_n"]), None, None)
    else:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(c["mesh"], spec))


@jax.custom_vjp
def _ste(w, dq):
    return dq


def _ste_fwd(w, dq):
    return dq, None


def _ste_bwd(_, g):
    # gradient flows straight through to the (sharded) master weight; SPMD
    # turns the resharding into the usual grad reduce-scatter
    return g, None


_ste.defvjp(_ste_fwd, _ste_bwd)


def fsdp_int8_gather(w: jax.Array, tp_dim: int = 0) -> jax.Array:
    """FSDP weight gather at int8 width (§Perf B2, beyond-paper).

    The sharded bf16 master weight is block-quantized locally (per-row
    scales over the last dim), the INT8 values are what cross the network
    (sharding constraint releases only the dp axes), and dequantization is
    local.  Backward is straight-through: the cotangent goes to the bf16
    master, so the optimizer still sees full-precision gradients — this is
    I-BERT's integer-transport thesis applied to the FSDP fabric, cutting
    gather bytes 2x vs bf16.  No-op unless hints(int8_gather=True).
    """
    c = _cfg()
    if isinstance(w, dict) or c is None or not c.get("int8_gather"):
        return w  # already-quantized serving leaves pass through
    from jax.sharding import NamedSharding
    s = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1,
                            keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    parts = [None] * w.ndim
    if w.shape[tp_dim] % c["tp_n"] == 0:
        parts[tp_dim] = c["tp"]
    sharding = NamedSharding(c["mesh"], P(*parts))
    q = jax.lax.with_sharding_constraint(q, sharding)  # int8 crosses links
    s = jax.lax.with_sharding_constraint(
        s, NamedSharding(c["mesh"], P(*(parts[:-1] + [None]))))
    dq = (q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16))
    return _ste(w, dq)
