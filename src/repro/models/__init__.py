from repro.models.transformer import Model, init_params, make_model  # noqa: F401
