"""Attention: GQA/MQA, RoPE, sliding window, KV-chunked online softmax, decode.

Long-prefill shapes (32k) cannot materialize (S,S) score matrices even
sharded; `_chunked_attention` streams KV blocks through an online-softmax
scan (flash-attention recurrence expressed in jax.lax so XLA/SPMD can shard
it; the Pallas-fused variant is a §Perf item).  Decode attends one query row
against the full cache.  Segment ids implement the paper's no-padding packed
sequences (§7.1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, apply_rope, dense_init

Params = Dict[str, Any]

DENSE_ATTN_MAX_KV = 1024  # above this, use the KV-chunked online-softmax path
# bigger KV chunks = proportionally fewer (m,l,acc) carry round-trips
# through HBM in the online-softmax scan (§Perf C2a: the scan-carry traffic
# dominated the 32k-prefill memory term); 1024 balances that against the
# checkpointed-backward recompute peak, which grows with chunk size
KV_CHUNK = 1024

NEG_INF = -1e30


def attn_init(key, cfg) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, nh * hd),
        "wk": dense_init(ks[1], d, nkv * hd),
        "wv": dense_init(ks[2], d, nkv * hd),
        "wo": dense_init(ks[3], nh * hd, d),
    }


def _split_heads(x, n):  # (B,S,n*hd) -> (B,S,n,hd)
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _repeat_kv(k, q_per_kv):  # (B,S,nkv,hd) -> (B,S,nh,hd)
    # retained for reference; the attention paths below use GROUPED einsums
    # instead — materializing the q_per_kv-expanded KV cache cost up to
    # 7x cache bytes (deepseek decode: 134 GB/chip, §Perf 0.7)
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _mask(sq: int, sk: int, q_pos, k_pos, causal: bool, window: int,
          q_seg=None, k_seg=None):
    """(B, sq, sk) bool mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], sq, sk), dtype=bool)
    if causal:
        m &= q_pos[:, :, None] >= k_pos[:, None, :]
    if window:
        m &= q_pos[:, :, None] - k_pos[:, None, :] < window
    if q_seg is not None:
        m &= q_seg[:, :, None] == k_seg[:, None, :]
    return m


def _gq_scores(q, k) -> jax.Array:
    """Grouped scores without expanding KV: q:(B,Sq,H,hd) k:(B,Sk,KVH,hd)
    -> (B,H,Sq,Sk).  Materializing repeat_kv cost up to 7x cache bytes
    (deepseek decode: 134 GB/chip, §Perf 0.7)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    q5 = q.reshape(b, sq, kvh, h // kvh, hd)
    s = jnp.einsum("bqngd,bknd->bngqk", q5, k)
    return s.reshape(b, h, sq, k.shape[1])


def _gq_pv(p, v) -> jax.Array:
    """p:(B,H,Sq,Sk) x v:(B,Sk,KVH,hd) -> (B,Sq,H,hd), grouped."""
    b, h, sq, sk = p.shape
    kvh, hd = v.shape[2], v.shape[3]
    p5 = p.reshape(b, kvh, h // kvh, sq, sk)
    out = jnp.einsum("bngqk,bknd->bqngd", p5, v)
    return out.reshape(b, sq, h, hd)


def _dense_attention(q, k, v, mask) -> jax.Array:
    """q:(B,Sq,H,hd) k/v:(B,Sk,KVH,hd) mask:(B,Sq,Sk)."""
    s = _gq_scores(q, k).astype(jnp.float32)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask[:, None], -1, keepdims=True), p, 0.0)
    return _gq_pv(p.astype(q.dtype), v)


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window,
                       q_seg=None, k_seg=None) -> jax.Array:
    """Online-softmax over KV chunks; O(Sq * KV_CHUNK) live scores."""
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    chunk = min(KV_CHUNK, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
        if k_seg is not None:
            k_seg = jnp.pad(k_seg, ((0, 0), (0, pad)), constant_values=-2)
    n_chunks = k.shape[1] // chunk

    kc = k.reshape(b, n_chunks, chunk, kvh, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).swapaxes(0, 1)
    pc = k_pos.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    sc = (k_seg.reshape(b, n_chunks, chunk).swapaxes(0, 1)
          if k_seg is not None else None)

    def body(carry, xs):
        m_run, l_run, acc = carry
        if sc is not None:
            kx, vx, px, sx = xs
        else:
            kx, vx, px = xs
            sx = None
        s = _gq_scores(q, kx).astype(jnp.float32)
        msk = _mask(sq, chunk, q_pos, px, causal, window, q_seg, sx)
        s = jnp.where(msk[:, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(-1)
        # NB: a bf16 accumulator carry was tried (§Perf C2a-refuted): it
        # halves carry bytes but compounds rescaling error over 16+ chunks
        # and flipped greedy tokens in the serving tests — f32 it stays.
        acc = acc * alpha[..., None] + _gq_pv(
            p.astype(q.dtype), vx).swapaxes(1, 2).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, h, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, hd), jnp.float32),
    )
    xs = (kc, vc, pc) + ((sc,) if sc is not None else ())
    # checkpoint the chunk body: backward recomputes each chunk's scores
    # instead of saving (B,H,Sq,chunk) residuals per step (flash-attn-style
    # memory: carries only) — §Perf iteration 1
    (m_run, l_run, acc), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)  # (B,Sq,H,hd)


def _windowed_attention(q, k, v, q_pos, k_pos, window,
                        q_seg=None, k_seg=None) -> jax.Array:
    """Causal sliding-window attention in O(S*2W): query blocks of size W
    attend only to their own and the previous KV block (§Perf A4 — the
    full chunked path wastes 8x attention FLOPs at 32k/W=2048)."""
    b, s, h, hd = q.shape
    blk = window
    pad = (-s) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=2**30)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
        if q_seg is not None:
            q_seg = jnp.pad(q_seg, ((0, 0), (0, pad)), constant_values=-2)
            k_seg = jnp.pad(k_seg, ((0, 0), (0, pad)), constant_values=-3)
    nb = q.shape[1] // blk

    def blocks(a):  # (B, nb, blk, ...)
        return a.reshape(b, nb, blk, *a.shape[2:])

    qb, kb, vb = blocks(q), blocks(k), blocks(v)
    qpb, kpb = blocks(q_pos), blocks(k_pos)
    # KV for block i = concat(block i-1, block i); block -1 is zeros/masked
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kpprev = jnp.concatenate(
        [jnp.full_like(kpb[:, :1], 2**30), kpb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (B, nb, 2*blk, H, hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    kp2 = jnp.concatenate([kpprev, kpb], axis=2)
    if q_seg is not None:
        qsb, ksb = blocks(q_seg), blocks(k_seg)
        ksprev = jnp.concatenate(
            [jnp.full_like(ksb[:, :1], -3), ksb[:, :-1]], axis=1)
        ks2 = jnp.concatenate([ksprev, ksb], axis=2)

    def one(qc, kc, vc, qp, kp, qs=None, ks=None):
        msk = _mask(blk, 2 * blk, qp, kp, True, window, qs, ks)
        return _dense_attention(qc, kc, vc, msk)

    args = (qb, k2, v2, qpb, kp2) + ((qsb, ks2) if q_seg is not None else ())
    out = jax.vmap(one, in_axes=1, out_axes=1)(*args)
    return out.reshape(b, nb * blk, h, hd)[:, :s]


def attention(x: jax.Array, p: Params, cfg, positions: jax.Array,
              segment_ids: Optional[jax.Array] = None,
              cache: Optional[Dict[str, jax.Array]] = None,
              pos_contiguous: bool = False,
              page_table: Optional[jax.Array] = None,
              active: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full attention block.

    Training/prefill: cache=None -> self-attention over x.
    Decode: cache={'k','v','pos'} -> write x's KV at cache['pos'], attend to
    the whole (ring-buffered if local_window) cache.
    pos_contiguous: caller guarantees positions == broadcast(arange(S)) (no
    pad sentinels), so the purely positional mask of the Pallas
    flash-attention kernel is exact and long prefill may route through it.
    page_table: (B, MAXP) int32 — marks the cache as a *paged* KV arena
    (`k`/`v`: (P, ps, KVH, hd), `kpos`: (P, ps)): lane b's logical position
    q lives at arena page page_table[b, q // ps], offset q % ps.  Decode
    writes are scattered through the table; `active` gates them (inactive
    lanes write to the allocator's trash page 0 with sentinel kpos, so a
    parked lane can never corrupt live pages — the paged analogue of the
    dense path's cache_map where-masking).
    """
    from repro.kernels import ops as kops
    from repro.models.layers import dense
    from repro.models.shard_hints import (
        fsdp_int8_gather, hint, paged_shard_ctx,
    )

    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    wq = fsdp_int8_gather(p["wq"], tp_dim=1)  # no-op unless enabled
    wk = fsdp_int8_gather(p["wk"], tp_dim=1)
    wv = fsdp_int8_gather(p["wv"], tp_dim=1)
    # NB: sharding k/v on head_dim to match a TP-sharded cache was tried
    # (§Perf A5-refuted): the score contraction then needs per-chunk psums,
    # 3x the collective bytes of the one-off cache-write reshard.
    q = hint(_split_heads(dense(x, wq), nh), "bshd")
    k = hint(_split_heads(dense(x, wk), nkv), "bshd")
    v = hint(_split_heads(dense(x, wv), nkv), "bshd")
    # q stays unscaled here: the fused prefill kernel applies 1/sqrt(hd)
    # itself, every other path takes the pre-scaled qs below
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qs = q * (1.0 / math.sqrt(hd))

    window = cfg.local_window
    impl = kops.default_impl()

    if cache is not None and page_table is not None and x.shape[1] > 1:
        # paged verify: Sq = T > 1 contiguous queries against the arena —
        # the speculative-decoding target pass (docs/serving.md
        # §speculative decoding).  Each of the T rows is scattered exactly
        # like the single-query decode write below, then each query attends
        # through the same paged kernel at its own absolute position; rows
        # ahead of a query carry kpos greater than its qpos, so the causal
        # mask hides them.  Row i of the output is therefore bitwise
        # identical to what a single-step paged decode at positions[:, i]
        # would have produced — which is what makes greedy verification
        # lossless.  Rejected speculation needs no cache cleanup: rewinding
        # the position counter leaves the garbage rows at kpos greater than
        # every future query position until overwritten (causally
        # unreachable).
        assert not window, "paged KV does not support sliding windows"
        b, t = x.shape[0], x.shape[1]
        ck, cv = cache["k"], cache["v"]  # (P, ps, KVH, hd)
        ps = ck.shape[1]
        quantized = "k_scale" in cache
        act = (jnp.ones((b,), bool) if active is None
               else active.astype(bool))
        # one batched (B*T)-row scatter: within a lane the T positions are
        # distinct, across lanes only exclusively-owned write pages are
        # touched, so the only duplicate indices are inactive rows on the
        # trash page — and those all write the same sentinel kpos, so
        # scatter order can't matter
        cpos = positions.astype(jnp.int32)  # (B, T)
        page = jnp.take_along_axis(page_table, cpos // ps, axis=1)
        wr_page = jnp.where(act[:, None], page, 0).reshape(-1)
        wr_off = jnp.where(act[:, None], cpos % ps, 0).reshape(-1)
        kpos_val = jnp.where(act[:, None], cpos, jnp.int32(2 ** 30))
        kpos = cache["kpos"].at[wr_page, wr_off].set(kpos_val.reshape(-1))
        kf = k.reshape(b * t, nkv, hd)
        vf = v.reshape(b * t, nkv, hd)
        if quantized:
            from repro.core.quant import kv_quantize
            kq, ksc = kv_quantize(kf)
            vq, vsc = kv_quantize(vf)
            ck = ck.at[wr_page, wr_off].set(kq)
            cv = cv.at[wr_page, wr_off].set(vq)
            cks = cache["k_scale"].at[wr_page, wr_off].set(ksc)
            cvs = cache["v_scale"].at[wr_page, wr_off].set(vsc)
        else:
            ck = ck.at[wr_page, wr_off].set(kf.astype(ck.dtype))
            cv = cv.at[wr_page, wr_off].set(vf.astype(cv.dtype))
        route = "pallas" if (impl == "pallas" and cfg.causal) else "ref"
        mesh_kw = {}
        ctx = paged_shard_ctx()
        if ctx is not None and nkv % ctx[2] == 0 and nh % ctx[2] == 0:
            mesh_kw = {"mesh": ctx[0], "axis": ctx[1]}
        # fold the T contiguous queries into the batch axis: ONE kernel
        # dispatch for the whole block — (B*T) lanes sharing the arena,
        # each query attending at its own absolute position.  Per-row
        # attention has no cross-batch reduction, so row (b, i) is bitwise
        # what a single-step paged decode at positions[b, i] would produce
        # — at one dispatch's cost instead of T.
        qf = qs.reshape(b * t, nh, hd)
        ptf = jnp.repeat(page_table, t, axis=0)  # (B*T, MAXP)
        qpf = cpos.reshape(-1)
        actf = jnp.repeat(act, t)
        if quantized:
            of = kops.paged_flash_decode_q(qf, ck, cv, cks, cvs, kpos, ptf,
                                           qpf, active=actf, impl=route,
                                           **mesh_kw)
        else:
            of = kops.paged_flash_decode(qf, ck.astype(q.dtype),
                                         cv.astype(q.dtype), kpos, ptf, qpf,
                                         active=actf, impl=route, **mesh_kw)
        out = of.reshape(b, t, nh, hd)
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
        if quantized:
            new_cache.update({"k_scale": cks, "v_scale": cvs})
    elif cache is None or x.shape[1] > 1:
        if x.shape[1] <= DENSE_ATTN_MAX_KV:
            msk = _mask(x.shape[1], x.shape[1], positions, positions,
                        cfg.causal, window, segment_ids, segment_ids)
            out = _dense_attention(qs, k, v, msk)
        elif (impl != "ref" and pos_contiguous and segment_ids is None
              and not window
              and kops.fused_grid_ok(
                  impl, x.shape[0] * nh, (-(-x.shape[1] // 256)) ** 2)):
            # long prefill on the Pallas kernel: online-softmax carries live
            # in VMEM instead of round-tripping HBM per KV chunk (§Perf C2a)
            out = kops.flash_attention(q, k, v, causal=cfg.causal, impl=impl)
        elif window and cfg.causal and x.shape[1] > 2 * window:
            out = _windowed_attention(qs, k, v, positions, positions,
                                      window, segment_ids, segment_ids)
        else:
            out = _chunked_attention(qs, k, v, positions, positions,
                                     cfg.causal, window, segment_ids,
                                     segment_ids)
        if cache is None:
            new_cache = None
        else:
            # prefill: write the (last `slots`) KV + their absolute positions
            s = x.shape[1]
            slots = cache["k"].shape[1]
            take = min(s, slots)
            kw, vw = k[:, -take:], v[:, -take:]
            pw = positions[:, -take:].astype(jnp.int32)
            idx = (jnp.arange(s - take, s, dtype=jnp.int32) % slots
                   if window else jnp.arange(take, dtype=jnp.int32))
            ck = cache["k"].at[:, idx].set(kw.astype(cache["k"].dtype))
            cv = cache["v"].at[:, idx].set(vw.astype(cache["v"].dtype))
            ckp = cache["kpos"].at[:, idx].set(pw)
            new_cache = {"k": ck, "v": cv, "kpos": ckp}
    elif page_table is not None:
        # paged decode: Sq == 1 against the global page arena.  The write
        # is a (page, offset) scatter through the lane's page table; the
        # copy-on-write alignment rule (serving engine) guarantees an
        # active lane's current write page is exclusively owned, so lanes
        # never race on a page.  Inactive lanes are redirected to the
        # trash page (0) with sentinel kpos instead of being where-masked
        # afterwards — an arena has no batch axis to mask over.
        assert not window, "paged KV does not support sliding windows"
        b = x.shape[0]
        ck, cv = cache["k"], cache["v"]  # (P, ps, KVH, hd)
        ps = ck.shape[1]
        quantized = "k_scale" in cache  # int8 arena + per-row scales
        cpos = positions[:, 0].astype(jnp.int32)
        act = (jnp.ones((b,), bool) if active is None
               else active.astype(bool))
        page = page_table[jnp.arange(b), cpos // ps]
        wr_page = jnp.where(act, page, 0)
        wr_off = jnp.where(act, cpos % ps, 0)
        kpos_val = jnp.where(act, cpos, jnp.int32(2 ** 30))
        if quantized:
            # the scatter quantizes the new row on the way in: one int8
            # row + one f32 scale per kv head, same (page, offset) address
            # as the values — inactive lanes' rows land in the trash page
            # with sentinel kpos exactly like the bf16 arena's
            from repro.core.quant import kv_quantize
            kq, ksc = kv_quantize(k[:, 0])  # (B, KVH, hd) int8, (B, KVH)
            vq, vsc = kv_quantize(v[:, 0])
            ck = ck.at[wr_page, wr_off].set(kq)
            cv = cv.at[wr_page, wr_off].set(vq)
            cks = cache["k_scale"].at[wr_page, wr_off].set(ksc)
            cvs = cache["v_scale"].at[wr_page, wr_off].set(vsc)
        else:
            ck = ck.at[wr_page, wr_off].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[wr_page, wr_off].set(v[:, 0].astype(cv.dtype))
        kpos = cache["kpos"].at[wr_page, wr_off].set(kpos_val)
        # the page-gathering kernels only route compiled: their grid is
        # (B, KVH, MAXP) and decode dispatches thousands of times, so the
        # interpreter's per-program overhead (~8x the jnp gather at
        # serving shapes) would dominate CPU serving — interpret CI
        # exercises the kernel bodies in tests/test_paged_kv.py and
        # tests/test_quant_kv.py; the jnp fallback is the gather oracle
        # (bitwise equal to the dense ref path on equal logical lengths)
        route = "pallas" if (impl == "pallas" and cfg.causal) else "ref"
        # plan-sharded serving (serve_exact hints context): the arena's
        # kv-head dim is partitioned over `model`, so the decode kernel
        # dispatch runs under shard_map — each model shard walks the
        # (replicated) page table over its own kv heads, the SPMD form of
        # the paper's per-head dotprod_softmax kernels behind the scatter
        # GMI.  Falls back to the unsharded call when the head counts
        # don't divide the axis (the plan replicated the arena then too).
        mesh_kw = {}
        ctx = paged_shard_ctx()
        if ctx is not None and nkv % ctx[2] == 0 and nh % ctx[2] == 0:
            mesh_kw = {"mesh": ctx[0], "axis": ctx[1]}
        if quantized:
            out = kops.paged_flash_decode_q(
                qs[:, 0], ck, cv, cks, cvs, kpos, page_table, cpos,
                active=act, impl=route, **mesh_kw)[:, None]
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "kpos": kpos}
        else:
            out = kops.paged_flash_decode(
                qs[:, 0], ck.astype(q.dtype), cv.astype(q.dtype), kpos,
                page_table, cpos, active=act, impl=route,
                **mesh_kw)[:, None]
            new_cache = {"k": ck, "v": cv, "kpos": kpos}
    else:
        # decode: Sq == 1; the token's absolute position comes from the
        # model-level counter (positions[:, 0]) — the cache itself is
        # position-metadata-free apart from per-slot kpos.
        # Cache writes are vmapped per-row dynamic updates: a scatter whose
        # batch coord is a scattered dim would make SPMD replicate the whole
        # KV cache (observed 133 GB/chip on deepseek decode, §Perf 0.7).
        ck, cv = cache["k"], cache["v"]  # (B,slots,nkv,hd)
        cpos = positions[:, 0].astype(jnp.int32)
        slot = (cpos % ck.shape[1]) if window else jnp.minimum(
            cpos, ck.shape[1] - 1)

        def _dus(buf, start, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val[None], start, axis=0)

        ck = jax.vmap(_dus)(ck, slot, k[:, 0].astype(ck.dtype))
        cv = jax.vmap(_dus)(cv, slot, v[:, 0].astype(cv.dtype))
        kpos = jax.vmap(_dus)(cache["kpos"], slot, cpos)
        if (impl != "ref" and cfg.causal
                and kops.fused_grid_ok(impl, x.shape[0], nkv,
                                       -(-ck.shape[1] // 256))):
            # fused split-KV decode: one VMEM pass over the slot cache, GQA
            # grouped in-kernel (no repeated-KV reads), kpos sentinel and
            # ring-buffer window masked from the same absolute positions
            out = kops.flash_decode(
                qs[:, 0], ck.astype(q.dtype), cv.astype(q.dtype), kpos,
                cpos, window=window, impl=impl)[:, None]
        else:
            msk = _mask(1, ck.shape[1], positions, kpos, cfg.causal, window)
            out = _dense_attention(qs, ck.astype(q.dtype),
                                   cv.astype(q.dtype), msk)
        new_cache = {"k": ck, "v": cv, "kpos": kpos}

    # serve_exact plans gather the head outputs here (the Fig. 14 gather
    # GMI before linear_o) so the replicated wo contraction is bit-exact;
    # serve_psum plans keep them head-sharded so the column-sharded wo
    # contraction stays partial (one all-reduce); no-ops everywhere else
    out = hint(out.reshape(x.shape[0], x.shape[1], nh * hd), "gather")
    out = hint(out, "psum")
    wo = fsdp_int8_gather(p["wo"], tp_dim=0)
    return dense(out, wo), new_cache


def init_paged_attn_cache(cfg, num_pages: int, page_size: int,
                          dtype=COMPUTE_DTYPE, quantized: bool = False):
    """Paged KV arena: a global page pool instead of per-lane slot rows.

    No batch axis — lanes address the arena through their page tables, and
    capacity is shared: HBM scales with the pages actually allocated, not
    max_batch * worst-case slot length.  kpos starts at the never-written
    sentinel everywhere (including the reserved trash page 0), and the
    serving engine re-sentinels a page's kpos on reallocation, so a page's
    previous occupant is unreachable by construction.

    quantized=True stores int8 k/v plus per-row per-kv-head f32 scale
    planes (`k_scale`/`v_scale`, core/quant.kv_quantize): ~half the bytes
    per cache row, so a fixed HBM budget holds ~2x the pages.  The scales
    live in the arena — a radix-shared prefix page carries its scales with
    it, so every lane reading the page dequantizes identically.
    """
    assert not cfg.local_window, "paged KV does not support sliding windows"
    kv_shape = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        "k": jnp.zeros(kv_shape, jnp.int8 if quantized else dtype),
        "v": jnp.zeros(kv_shape, jnp.int8 if quantized else dtype),
        "kpos": jnp.full((num_pages, page_size), 2**30, jnp.int32),
    }
    if quantized:
        cache["k_scale"] = jnp.zeros(kv_shape[:3], jnp.float32)
        cache["v_scale"] = jnp.zeros(kv_shape[:3], jnp.float32)
    return cache


def init_attn_cache(cfg, batch: int, seq_len: int, dtype=COMPUTE_DTYPE):
    """KV cache; ring buffer of local_window slots when windowed."""
    slots = min(seq_len, cfg.local_window) if cfg.local_window else seq_len
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        # per-slot absolute positions; 2^30 marks never-written slots so the
        # causal mask excludes them (also excludes padded prompt columns)
        "kpos": jnp.full((batch, slots), 2**30, jnp.int32),
    }
