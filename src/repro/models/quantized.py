"""W8A8 int8 serving path (§Perf C — the paper's I-BERT technique applied
to the assigned LM archs at datacenter scale).

Weights are quantized OFFLINE (per-output-channel symmetric int8, stored as
{"q": int8, "s": f32}); activations are quantized dynamically per tensor at
each projection; the matmul runs int8 x int8 -> int32 on the MXU (2x bf16
peak on v5e) and dequantizes into bf16.  Attention math (softmax, RoPE) and
the LM head stay bf16 — the I-BERT recipe's integer heavy-math/float
touch-point split.

models/layers.dense() dispatches here when it sees a quantized leaf, so the
whole backbone picks this up when params are converted with
`quantize_params_for_serving`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

# leaves that carry the serving-critical GEMMs (2D attention/MLP
# projections; 3D MoE expert tensors and recurrent-cell projections stay
# bf16 in this iteration — noted in EXPERIMENTS.md §Perf C)
QUANT_NAMES = ("wq", "wk", "wv", "wo", "wi", "wg", "shared_wi", "shared_wg",
               "shared_wo")


def quantize_leaf(w: jax.Array) -> Dict[str, jax.Array]:
    """Per-output-channel (last dim) symmetric int8."""
    amax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2,
                               keepdims=True), 1e-8)
    s = amax / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.float32)}


def quantize_params_for_serving(params: Any) -> Any:
    """Offline conversion: every QUANT_NAMES >=2D leaf -> {"q","s"}."""

    def go(tree, path=()):
        if isinstance(tree, dict):
            return {k: go(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        if name in QUANT_NAMES and hasattr(tree, "ndim") and tree.ndim == 2:
            return quantize_leaf(tree)
        return tree

    return go(params)


def is_quantized(w: Any) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def qdense(x: jax.Array, w: Dict[str, jax.Array]) -> jax.Array:
    """dynamic-A8 x static-W8 -> int32 -> bf16 (per-tensor act scale).

    On TPU the GEMM routes through the Pallas `int8_matmul` kernel (VMEM
    int32 accumulator tile across the K loop — the paper's Linear module);
    elsewhere it stays the jnp int8 `dot_general` with the identical
    INT8xINT8->INT32 contract (the kernel's oracle), because the
    interpreter's per-program replay would dominate CPU decode dispatches.
    The int8 operand and int32 accumulator are pinned batch-sharded /
    feature-sharded: SPMD's int8 dot partitioning is weaker than f32/bf16
    and gathers operands without the constraints (§Perf C2b)."""
    from repro.kernels import ops as kops
    from repro.models.shard_hints import hint

    ax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-8)
    s_x = ax / 127.0
    x8 = jnp.clip(jnp.round(x.astype(jnp.float32) / s_x), -127, 127
                  ).astype(jnp.int8)
    if x8.ndim == 3:
        x8 = hint(x8, "btd")
    if kops.default_impl() == "pallas":
        x2 = x8.reshape(-1, x8.shape[-1])
        acc = kops.int8_matmul(x2, w["q"], jnp.float32(1.0),
                               jnp.float32(1.0), impl="pallas")
        acc = acc.reshape(x8.shape[:-1] + (w["q"].shape[-1],))
    else:
        acc = jax.lax.dot_general(
            x8, w["q"],
            (((x.ndim - 1,), (w["q"].ndim - 2,)), ((), ())),
            preferred_element_type=jnp.int32)
    if acc.ndim == 3:
        acc = hint(acc, "btf")
    return (acc.astype(jnp.float32) * (s_x * w["s"])).astype(jnp.bfloat16)
