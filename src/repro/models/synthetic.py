"""Deterministic synthetic-task fitting for accuracy-sensitive serving runs.

The int8 KV-cache measurements (benchmarks/run.py serve_quant,
tests/test_quant_kv.py) compare greedy token streams across numerics.  A
random-init model is the wrong instrument for that: its top-2 logit gaps
cluster near zero, so *any* sub-percent perturbation — int8 KV noise, but
equally bf16 summation-order changes — flips ~1-2% of greedy steps and the
streams diverge irrecoverably.  Quantization accuracy is only meaningful on
a model with a confident predictive distribution, which is what every real
serving deployment has.

`fit_affine_lm` trains the reduced config on an *affine-cycle* corpus —
each sequence follows ``t[i+1] = (t[0] + step * (i+1)) % vocab`` with a
per-sequence step — to near-zero loss in ~1k adam steps (tens of seconds on
a CPU CI box, cached per process).  Predicting the next token requires the
step, which is only recoverable from *two* consecutive tokens, so the model
must actually read its KV cache at decode time: a corrupted page, scale, or
page-table entry still shows up as stream divergence.  In-distribution
prompts come from `affine_prompts`.

Everything is seeded and jit-compiled once, so the fitted weights are
reproducible across runs of the same jax version.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

STEP_RANGE = (1, 13)  # per-sequence affine steps drawn from [1, 13)

_FIT_CACHE: Dict[Tuple, object] = {}


def affine_batch(rng: np.random.Generator, vocab: int, batch: int = 16,
                 seq: int = 32, disagree_every: int = 0,
                 disagree_delta: int = 1):
    """(tokens, labels) minibatch of affine cycles.

    disagree_every=E > 0 deviates the corpus: any token whose clean affine
    value is ≡ 0 (mod E) is replaced by value + disagree_delta.  The rule
    is a function of the *predicted value* (recoverable from any two
    consecutive clean tokens), not of absolute position, so a model fits
    it as easily as the clean task — two models fitted with different E
    then disagree on ~1/E of greedy steps, which is how the speculative-
    decoding benchmarks dial draft/target agreement (benchmarks/run.py
    serve_spec)."""
    t0 = rng.integers(0, vocab, (batch, 1))
    step = rng.integers(*STEP_RANGE, (batch, 1))
    toks = (t0 + step * np.arange(seq + 1)) % vocab
    if disagree_every:
        toks = np.where(toks % disagree_every == 0,
                        (toks + disagree_delta) % vocab, toks)
    return jnp.asarray(toks[:, :-1]), jnp.asarray(toks[:, 1:])


def affine_prompts(rng: np.random.Generator, n: int, vocab: int,
                   len_range: Tuple[int, int] = (6, 20)) -> List[np.ndarray]:
    """n in-distribution prompts (each its own start token and step)."""
    out = []
    for _ in range(n):
        t0 = int(rng.integers(0, vocab))
        step = int(rng.integers(*STEP_RANGE))
        ln = int(rng.integers(*len_range))
        out.append(((t0 + step * np.arange(ln)) % vocab).astype(np.int32))
    return out


def fit_affine_lm(model, steps: int = 1000, lr: float = 1e-2, seed: int = 0,
                  disagree_every: int = 0, disagree_delta: int = 1):
    """Fit `model` (a transformer.Model) to the affine-cycle task.

    Plain adam with f32 moments over the bf16 weights; the (model config
    name, steps, lr, seed, disagreement) result is cached per process
    because the benchmarks and tests all want the same fitted instrument.
    `disagree_every` deviates the training corpus (see `affine_batch`) so
    a draft model can be fitted to agree with a clean-fitted target on a
    controllable fraction of greedy steps.
    """
    key = (model.cfg.name, steps, lr, seed, disagree_every, disagree_delta)
    if key in _FIT_CACHE:
        return _FIT_CACHE[key]
    from repro.models.transformer import init_params

    vocab = model.cfg.vocab_size
    params = init_params(model.cfg, jax.random.PRNGKey(seed))
    m0 = jax.tree.map(lambda w: jnp.zeros_like(w, jnp.float32), params)
    v0 = jax.tree.map(lambda w: jnp.zeros_like(w, jnp.float32), params)

    def loss_fn(p, t, l):
        return model.loss(p, {"tokens": t, "labels": l})

    @jax.jit
    def step_fn(p, m, v, t, l, i):
        loss, g = jax.value_and_grad(loss_fn)(p, t, l)
        m = jax.tree.map(
            lambda a, b: 0.9 * a + 0.1 * b.astype(jnp.float32), m, g)
        v = jax.tree.map(
            lambda a, b: 0.99 * a + 0.01 * jnp.square(b.astype(jnp.float32)),
            v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** i), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.99 ** i), v)
        p = jax.tree.map(
            lambda w, a, b: (w.astype(jnp.float32)
                             - lr * a / (jnp.sqrt(b) + 1e-8)).astype(w.dtype),
            p, mh, vh)
        return p, m, v, loss

    rng = np.random.default_rng(seed)
    m, v = m0, v0
    for i in range(1, steps + 1):
        t, l = affine_batch(rng, vocab, disagree_every=disagree_every,
                            disagree_delta=disagree_delta)
        params, m, v, _ = step_fn(params, m, v, t, l, jnp.float32(i))
    _FIT_CACHE[key] = params
    return params
