"""Sharded, async, atomic checkpointing (fault-tolerance substrate).

The paper's multi-cluster design keeps a per-cluster input buffer so that a
failed cluster can be reconfigured and resume without draining the others
(§6).  The training-side equivalent is checkpoint/restart:

  * atomic: write to `step_XXXX.tmp/`, fsync, rename — a crash mid-save
    never corrupts the latest good checkpoint
  * async: device->host transfer happens synchronously (cheap), file IO on a
    background thread so the train loop isn't blocked
  * sharded-aware: leaves are fetched with jax.device_get (which gathers
    addressable shards); layout metadata (paths, shapes, dtypes) lives in a
    manifest with per-file checksums for integrity checks on restore
  * keeps the last `keep` checkpoints, prunes older ones
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=()) -> List[Tuple[Tuple[str, ...], Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten(tree[k], prefix + (str(k),))
        return out
    return [(prefix, tree)]


def _unflatten(items: Dict[Tuple[str, ...], Any]):
    root: Dict = {}
    for path, v in items.items():
        cur = root
        for k in path[:-1]:
            cur = cur.setdefault(k, {})
        cur[path[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        host = {p: np.asarray(jax.device_get(v)) for p, v in _flatten(tree)}
        self.wait()  # at most one outstanding async save
        fut = self._pool.submit(self._write, step, host)
        self._pending = fut
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host: Dict[Tuple[str, ...], np.ndarray]):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for path, arr in host.items():
            name = "__".join(path) + ".npy"
            fp = os.path.join(tmp, name)
            np.save(fp, arr)
            with open(fp, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["leaves"]["/".join(path)] = {
                "file": name, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": digest,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None,
                template: Any = None) -> Tuple[int, Any]:
        """Returns (step, pytree).  `shardings`: optional matching pytree of
        NamedShardings to place leaves directly on the mesh (resharding on
        restore = elastic restart onto a different mesh).  `template`:
        optional structure to restore into (preserves empty sub-dicts,
        which have no leaves and thus no files)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shard_map_flat = (dict(_flatten(shardings))
                          if shardings is not None else {})
        items = {}
        for key, meta in manifest["leaves"].items():
            fp = os.path.join(d, meta["file"])
            with open(fp, "rb") as f:
                raw = f.read()
            if hashlib.sha256(raw).hexdigest() != meta["sha256"]:
                raise IOError(f"checksum mismatch for {key} in step {step}")
            arr = np.load(fp)
            if str(arr.dtype) != meta["dtype"]:
                # bf16 & friends round-trip through raw views on some numpy
                # versions: restore the manifest dtype explicitly
                import ml_dtypes  # noqa: F401
                arr = arr.view(np.dtype(meta["dtype"]))
            path = tuple(key.split("/"))
            sh = shard_map_flat.get(path)
            items[path] = (jax.device_put(arr, sh) if sh is not None
                           else jnp.asarray(arr))
        if template is not None:
            def fill(sub, prefix=()):
                if isinstance(sub, dict):
                    return {k: fill(v, prefix + (str(k),))
                            for k, v in sub.items()}
                return items[prefix]

            return step, fill(template)
        return step, _unflatten(items)
