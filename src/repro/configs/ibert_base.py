"""ibert-base — the paper's own model: integer-only RoBERTa-base encoder.

I-BERT [Kim et al. 2021, arXiv:2101.01321] quantization of RoBERTa-base:
L=12 encoders, A=12 heads, H=768, d_ff=3072, vocab=50265 (RoBERTa), max
sequence length 128 (GLUE).  Bidirectional encoder: no causal mask, no KV
cache — decode cells do not apply; the paper evaluates latency/throughput
over sequence lengths 1..128 which our benchmarks reproduce.
"""
from repro.configs.base import ModelConfig, register


@register("ibert-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="ibert-base",
        family="ibert",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=50265,
        mlp_style="mlp",
        act="gelu",
        norm="layernorm",
        causal=False,
        max_seq_len=512,
        skip_cells=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        skip_reason=(
            "ibert-base is the paper's own encoder-only model (max seq 512, "
            "no decode step); it is exercised by the paper-table benchmarks, "
            "not the assigned LM shape cells"
        ),
    )
