"""deepseek-coder-33b — llama-arch dense. [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("deepseek-coder-33b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        mlp_style="swiglu",
        act="silu",
        rope_theta=100_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
