"""moonshot-v1-16b-a3b — kimi/moonlight-style MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf].  Exact assigned dims: 48L d_model=2048
16H (GQA kv=16) d_ff=1408 (per expert) vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("moonshot-v1-16b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        n_experts=64,
        top_k=6,
        moe_every=1,
        n_shared_experts=2,
        mlp_style="swiglu",
        act="silu",
        rope_theta=50_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
