from repro.configs.base import (
    SHAPE_CELLS,
    ModelConfig,
    ShapeCell,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "SHAPE_CELLS",
    "ModelConfig",
    "ShapeCell",
    "get_config",
    "list_archs",
    "register",
]
