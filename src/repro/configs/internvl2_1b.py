"""internvl2-1b — InternViT + Qwen2-0.5B-style LM backbone. [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The InternViT vision
frontend is a STUB: input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("internvl2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        tie_embeddings=True,
        mlp_style="swiglu",
        act="silu",
        rope_theta=1_000_000.0,
        frontend="vlm_stub",
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
