"""Config system: one ModelConfig per assigned architecture plus the paper's own I-BERT.

The registry maps ``--arch <id>`` names to config factories.  Every config is a
frozen dataclass so it can be hashed into jit static args and embedded in
ClusterPlans.  ``reduced()`` returns a small same-family config for CPU smoke
tests; full configs are only ever lowered via the dry-run (ShapeDtypeStructs,
no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shape cells (assigned input-shape set; same 4 cells for every LM-family arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | ibert
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    local_window: int = 0  # >0 -> sliding-window attention width
    causal: bool = True

    # mlp
    mlp_style: str = "swiglu"  # swiglu (3 mats) | mlp (2 mats) | none
    act: str = "silu"  # silu | gelu | relu2

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE on layers where (layer % moe_every == moe_every-1)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): block pattern, e.g. ("rglru","rglru","attn")
    block_pattern: Tuple[str, ...] = ()
    rnn_width: int = 0  # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4

    # xlstm
    slstm_every: int = 0  # 0 = no sLSTM blocks; else every k-th block is sLSTM
    proj_factor: float = 2.0

    # embeddings / io
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    frontend: str = "none"  # none | audio_stub | vlm_stub
    max_seq_len: int = 524_288

    # integer (I-BERT) serving path available for this arch
    int8_path: bool = True

    # shape-cell applicability: cells listed here are skipped (with reason)
    skip_cells: Tuple[str, ...] = ()
    skip_reason: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name

    # -- derived ------------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def block_kind(self, layer: int) -> str:
        """Kind of sequence-mixing block at `layer`."""
        if self.family == "hybrid" and self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        if self.family == "ssm":
            if self.slstm_every and (layer % self.slstm_every == self.slstm_every - 1):
                return "slstm"
            return "mlstm"
        return "attn"

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and (layer % self.moe_every == self.moe_every - 1)

    # -- parameter counting (analytic; used for MODEL_FLOPS roofline term) --

    def attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def ffn_params(self, d_ff: Optional[int] = None) -> int:
        d_ff = self.d_ff if d_ff is None else d_ff
        if d_ff == 0 or self.mlp_style == "none":
            return 0
        mats = 3 if self.mlp_style == "swiglu" else 2
        return mats * self.d_model * d_ff

    def _recurrent_block_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "rglru":
            w = self.rnn_width or d
            # in/out proj + conv + gates (input & recurrence) + per-channel a
            return 2 * d * w + self.conv_width * w + 2 * w * w + 2 * w
        if kind == "mlstm":
            inner = int(self.proj_factor * d)
            nh = self.n_heads
            ih = inner // nh
            dk = ih // 2
            qkv = nh * (2 * ih * dk + ih * ih)  # block-diagonal per head
            return 2 * d * inner + qkv + inner * d + 3 * inner  # up(z,gate)+qkv+down+gates
        if kind == "slstm":
            nh = self.n_heads
            dh = d // nh
            gates_in = 4 * d * d
            gates_rec = 4 * nh * dh * dh  # block-diagonal recurrent mats
            glu = int(2 * d * (4 * d / 3))  # post-up GLU FFN (factor 4/3)
            return gates_in + gates_rec + glu
        raise ValueError(kind)

    def layer_params(self, layer: int) -> int:
        kind = self.block_kind(layer)
        mix = self.attn_params() if kind == "attn" else self._recurrent_block_params(kind)
        if self.is_moe_layer(layer):
            ffn = self.n_experts * self.ffn_params()
            ffn += self.n_shared_experts * self.ffn_params()
            ffn += self.d_model * self.n_experts  # router
        else:
            ffn = self.ffn_params() if self.family != "ssm" else (
                0 if kind == "mlstm" else 0  # slstm GLU counted inside block
            )
        norms = 2 * self.d_model
        return mix + ffn + norms

    def layer_active_params(self, layer: int) -> int:
        kind = self.block_kind(layer)
        mix = self.attn_params() if kind == "attn" else self._recurrent_block_params(kind)
        if self.is_moe_layer(layer):
            ffn = self.top_k * self.ffn_params()
            ffn += self.n_shared_experts * self.ffn_params()
            ffn += self.d_model * self.n_experts
        else:
            ffn = self.ffn_params() if self.family != "ssm" else 0
        return mix + ffn + 2 * self.d_model

    def embed_params(self) -> int:
        e = self.vocab_size * self.d_model
        return e if self.tie_embeddings else 2 * e

    def param_count(self) -> int:
        return self.embed_params() + sum(self.layer_params(l) for l in range(self.n_layers))

    def active_param_count(self) -> int:
        return self.embed_params() + sum(
            self.layer_active_params(l) for l in range(self.n_layers)
        )

    # -- reduced config for smoke tests --------------------------------------

    def reduced(self) -> "ModelConfig":
        """Small same-family config: runs one fwd/train step on CPU."""
        d = 64
        nh = 4
        nkv = max(1, min(self.n_kv_heads, 2))
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.family in ("hybrid", "ssm") else 2),
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            head_dim=16,
            local_window=min(self.local_window, 32) if self.local_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            rnn_width=d if self.rnn_width else 0,
            max_seq_len=128,
        )
        if self.family == "hybrid":
            kw["n_layers"] = max(kw["n_layers"], len(self.block_pattern))
        if self.slstm_every:
            kw["slstm_every"] = 2
            kw["n_layers"] = 4
        return replace(self, **kw)

    def cells(self) -> List[ShapeCell]:
        return [c for n, c in SHAPE_CELLS.items() if n not in self.skip_cells]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        moonshot_v1_16b_a3b,
        llama4_maverick_400b_a17b,
        smollm_135m,
        phi3_medium_14b,
        deepseek_coder_33b,
        minitron_8b,
        recurrentgemma_2b,
        musicgen_medium,
        internvl2_1b,
        xlstm_1_3b,
        ibert_base,
    )

    _LOADED = True


FULL_ATTENTION_SKIP = (
    "long_500k requires sub-quadratic sequence mixing; this arch is pure "
    "full-attention (524k-token KV prefill is quadratic) — skipped per brief, "
    "see DESIGN.md §5"
)
