"""smollm-135m — llama-arch small dense model. [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, tied embeddings.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("smollm-135m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
        mlp_style="swiglu",
        act="silu",
        rope_theta=10_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
