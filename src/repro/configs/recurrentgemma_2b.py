"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 2 recurrent : 1 attn.

[arXiv:2402.19427; hf].  26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
Local attention window 2048.  Runs long_500k (sub-quadratic).
"""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn"),
        rnn_width=2560,
        conv_width=4,
        local_window=2048,
        tie_embeddings=True,
        mlp_style="swiglu",
        act="gelu",
        rope_theta=10_000.0,
    )
