"""minitron-8b — pruned nemotron (squared-ReLU 2-matrix FFN). [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("minitron-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        mlp_style="mlp",
        act="relu2",
        rope_theta=10_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
