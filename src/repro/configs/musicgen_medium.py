"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings; the backbone here is the transformer decoder only.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        mlp_style="mlp",
        act="gelu",
        norm="layernorm",
        frontend="audio_stub",
        rope_theta=10_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
