"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Assigned dims: 48L
d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.

To match the public ~400B-total/17B-active shape, MoE layers are interleaved
(every 2nd layer, `moe_every=2`) with one shared expert, as in the released
Maverick config; dense layers use the same d_ff.  Total ≈ 397B, active ≈ 17B.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        n_experts=128,
        top_k=1,
        moe_every=2,
        n_shared_experts=1,
        mlp_style="swiglu",
        act="silu",
        rope_theta=500_000.0,
        skip_cells=("long_500k",),
        skip_reason=FULL_ATTENTION_SKIP,
    )
