"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1). [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 vocab=50304.  d_ff=0 per assignment: mLSTM blocks
use pre-up-projection (proj_factor=2) and carry no separate FFN; every 8th
block is an sLSTM block with a post-up GLU.  Runs long_500k (recurrent, O(1)
state per token).
"""
from repro.configs.base import ModelConfig, register


@register("xlstm-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        mlp_style="none",
        slstm_every=8,
        proj_factor=2.0,
        conv_width=4,
        norm="layernorm",
        tie_embeddings=True,
    )
