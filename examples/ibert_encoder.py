"""The paper's proof-of-concept, end to end (§7/§8): calibrate, quantize,
run the INTEGER I-BERT encoder, validate against the float oracle, and
reproduce the Table-1/Table-2 latency methodology at small scale.

  PYTHONPATH=src python examples/ibert_encoder.py
"""
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.latency_model import StageTiming, total_latency
from repro.models import ibert as ib


def main():
    cfg = get_config("ibert-base")
    # one encoder at true width, CPU-friendly depth (the paper also builds
    # ONE encoder and projects the 12-encoder pipeline via Eq. 1)
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    key = jax.random.PRNGKey(0)
    params = ib.init_ibert_params(cfg1, key)

    toks = jax.random.randint(key, (1, 128), 0, cfg1.vocab_size)
    act = ib.calibrate(params, cfg1, toks)
    qp = ib.quantize_ibert(params, cfg1, act)
    print(f"calibrated {len(act)} activation sites")

    out_f = ib.ibert_float_forward(params, cfg1, toks)
    out_i = ib.ibert_int_forward(qp, cfg1, toks, impl="ref")
    err = np.abs(np.asarray(out_i.dequantize()) - np.asarray(out_f))
    print(f"integer vs float: max={err.max():.4f} mean={err.mean():.4f} "
          f"(float std {np.asarray(out_f).std():.3f})")

    print("\nseq_len  T_encoder(ms)  Eq.1 12-encoder estimate(ms)")
    for s in (1, 8, 32, 64, 128):
        t_in = jax.random.randint(jax.random.PRNGKey(s), (1, s), 0,
                                  cfg1.vocab_size)
        f = jax.jit(lambda t: ib.ibert_int_forward(
            qp, cfg1, t, impl="ref").values)
        jax.block_until_ready(f(t_in))
        t0 = time.perf_counter()
        jax.block_until_ready(f(t_in))
        T = time.perf_counter() - t0
        full = total_latency(StageTiming(T=T, X=0.5325 * T, d=1.1e-6), 12)
        print(f"{s:7d}  {T*1e3:12.2f}  {full*1e3:10.2f}")
    print("\n(no-padding at the GLUE average length wins the same way the "
          "paper's Table 3 shows: compare seq 64 vs 128 rows)")


if __name__ == "__main__":
    main()
