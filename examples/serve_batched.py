"""End-to-end serving driver (the paper's kind: batched inference).

Batched requests with mixed prompt lengths flow through bucketed prefill +
greedy decode waves; reports the paper's latency/throughput quantities and
the no-padding utilization win (§7.1/§8.2).

  PYTHONPATH=src python examples/serve_batched.py [--arch smollm-135m]
"""
import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.packing import padded_batch, pack_sequences
from repro.models.transformer import init_params, make_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=4, buckets=(16, 32, 64))

    rng = np.random.default_rng(0)
    # GLUE-like variable lengths (paper: avg 38 of max 128 — scaled down)
    lengths = rng.integers(4, 30, args.requests)
    t0 = time.perf_counter()
    for i, n in enumerate(lengths):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=args.max_new))
    done = engine.run()
    wall = time.perf_counter() - t0

    lat = [(r.t_done - r.t_enqueue) * 1e3 for r in done]
    ttft = [(r.t_first_token - r.t_enqueue) * 1e3 for r in done]
    toks = sum(len(r.tokens_out) for r in done)
    print(f"served {len(done)} requests in {wall*1e3:.0f} ms "
          f"({toks/wall:.1f} tok/s)")
    print(f"latency ms: p50={np.percentile(lat,50):.0f} "
          f"p99={np.percentile(lat,99):.0f}; "
          f"ttft p50={np.percentile(ttft,50):.0f}")
    print(f"engine stats: {engine.stats}")

    # the no-padding story: utilization packed vs padded (paper Table 3/4)
    seqs = [rng.integers(0, 100, n).astype(np.int32) for n in lengths]
    packed = pack_sequences(seqs, 32)
    padded = padded_batch(seqs, 32)
    print(f"no-padding utilization: packed={packed.utilization:.2f} "
          f"({packed.tokens.shape[0]} rows) vs padded="
          f"{padded.utilization:.2f} ({padded.tokens.shape[0]} rows)")


if __name__ == "__main__":
    main()
