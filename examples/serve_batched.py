"""End-to-end serving demo: continuous batching vs wave scheduling.

A Poisson request stream (mixed GLUE-like prompt lengths, mixed decode
budgets) flows through both schedulers:

  * WaveEngine — the batch-synchronous baseline: batched prefill, decode to
    the slowest member, tear down, next wave;
  * ContinuousBatchingEngine — the paper's line-rate pipeline analogue
    (§8.2): requests are admitted into freed KV-cache slots between decode
    steps, so slots never idle while the queue is non-empty.

Reports throughput + TTFT for both, and the no-padding utilization win
(§7.1).

  PYTHONPATH=src python examples/serve_batched.py [--arch smollm-135m]
"""
import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.core.packing import padded_batch, pack_sequences
from repro.models.transformer import init_params, make_model
from repro.serving.engine import ContinuousBatchingEngine, WaveEngine
from repro.serving.stream import poisson_requests, replay


def run_engine(name, engine, stream):
    done, wall, tok_s, ttft = replay(engine, stream)
    toks = sum(len(r.tokens_out) for r in done)
    lat = [(r.t_done - r.t_enqueue) * 1e3 for r in done]
    print(f"{name:5s}: {len(done)} requests, {toks} tokens in "
          f"{wall*1e3:.0f} ms ({tok_s:.1f} tok/s); "
          f"ttft p50={np.percentile(ttft, 50):.0f}ms "
          f"p99={np.percentile(ttft, 99):.0f}ms; "
          f"latency p50={np.percentile(lat, 50):.0f}ms")
    print(f"       stats: {engine.stats}")
    return tok_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))

    wave = WaveEngine(model, params, max_batch=4, buckets=(16, 32, 64))
    cb = ContinuousBatchingEngine(model, params, max_batch=4,
                                  buckets=(16, 32, 64))
    reqs = poisson_requests(np.random.default_rng(0), args.requests,
                            cfg.vocab_size, len_range=(4, 30),
                            budgets=(2, 17), rate=args.rate)
    thr_w = run_engine("wave", wave, reqs)
    thr_c = run_engine("cb", cb, reqs)
    print(f"continuous/wave throughput: {thr_c/thr_w:.2f}x")

    # the no-padding story: utilization packed vs padded (paper Table 3/4)
    rng = np.random.default_rng(1)
    lengths = [len(r.prompt) for r in reqs]
    seqs = [rng.integers(0, 100, n).astype(np.int32) for n in lengths]
    packed = pack_sequences(seqs, 32)
    padded = padded_batch(seqs, 32)
    print(f"no-padding utilization: packed={packed.utilization:.2f} "
          f"({packed.tokens.shape[0]} rows) vs padded="
          f"{padded.utilization:.2f} ({padded.tokens.shape[0]} rows)")


if __name__ == "__main__":
    main()
