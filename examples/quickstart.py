"""Quickstart: build a model from the config registry, train a few steps,
generate a few tokens — the whole public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.train import make_train_step
from repro.models.transformer import init_params, make_model
from repro.optim.optimizer import cosine_schedule, make_optimizer
from repro.data.pipeline import TokenPipeline


def main():
    print("registered architectures:", ", ".join(list_archs()))

    cfg = get_config("smollm-135m").reduced()  # CPU-sized, same family
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e3:.0f}K params, {cfg.n_layers} layers)")

    opt_init, opt_update = make_optimizer(
        "adamw", cosine_schedule(5e-3, warmup=5, total=50))
    step = jax.jit(make_train_step(model, opt_update), donate_argnums=(0, 1))
    opt = opt_init(params)
    pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=32, seed=0)
    for i in range(50):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 10 == 0:
            print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}")

    # greedy generation with the KV cache
    prompt = jnp.asarray([[5, 17, 42, 7]], jnp.int32)
    caches = model.init_cache(1, 64)
    logits, caches = model.prefill(params, caches, tokens=prompt)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(7):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([toks[-1]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    print("generated:", toks)


if __name__ == "__main__":
    main()
