"""Fault-tolerant training driver demo: deterministic pipeline, async
checkpoints, an injected node failure at step 25, automatic recovery, and
straggler monitoring — the runtime substrate for the multi-pod deployment.

  PYTHONPATH=src python examples/train_fault_tolerant.py
(The same driver trains any --arch at full scale on real hardware:
 python -m repro.launch.train --arch deepseek-coder-33b --steps 10000 ...)
"""
import tempfile

from repro.launch import train


def main():
    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train.main([
            "--arch", "smollm-135m", "--reduced",
            "--steps", "60", "--batch", "8", "--seq", "64",
            "--lr", "5e-3",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "10",
            "--inject-failure-at", "25",
            "--pack",  # no-padding packed sequences (paper §7.1)
        ])
    report = out["report"]
    print(f"\nrecovered from steps {report.recovered_from}; "
          f"restarts={report.restarts}; completed={report.completed_steps}")
    assert report.completed_steps == 60


if __name__ == "__main__":
    main()
