"""Quantization arithmetic properties (hypothesis)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback draws (see detshim.py)
    from detshim import given, settings
    import detshim as st

import jax.numpy as jnp

from repro.core.quant import (
    INT8_MAX, QTensor, compute_scale, fake_quant, int8_matmul_ref, quantize,
    requantize,
)

_settings = dict(max_examples=40, deadline=None)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=200))
@settings(**_settings)
def test_quantize_roundtrip_error(vals):
    x = np.asarray(vals, np.float32)
    q = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(q.dequantize()) - x)
    # symmetric quantization error <= scale/2 per element
    assert err.max() <= float(q.scale) * 0.5 + 1e-6
    assert np.abs(np.asarray(q.values)).max() <= INT8_MAX


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_matmul_ref_matches_float(m, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (m, k)).astype(np.float32)
    b = rng.normal(0, 1, (k, 8)).astype(np.float32)
    qa, qb = quantize(jnp.asarray(a)), quantize(jnp.asarray(b))
    out = int8_matmul_ref(qa, qb)
    approx = np.asarray(out.values) * float(out.scale)
    exact = a @ b
    # error grows with sqrt(k) * scales
    tol = 3 * np.sqrt(k) * float(qa.scale) * float(qb.scale) * 127
    assert np.abs(approx - exact).max() <= tol + 1e-5


@given(st.integers(0, 1000))
@settings(**_settings)
def test_requantize_idempotent_scale(seed):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.integers(-10000, 10000, 64), jnp.int32)
    s = jnp.float32(0.01)
    out = requantize(acc, s, s * 100)  # shrink by 100x
    # |values| clipped to int8 and dequantized value preserved within 1 lsb
    deq_in = np.asarray(acc) * 0.01
    deq_out = np.asarray(out, np.float64) * 1.0
    mask = np.abs(deq_in) < 127 * 1.0
    assert np.abs(deq_out - deq_in)[mask].max() <= 0.5 + 1e-6


def test_fake_quant_fixedpoint():
    x = jnp.asarray(np.linspace(-2, 2, 255), jnp.float32)
    fq = fake_quant(x)
    fq2 = fake_quant(fq)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(fq2), atol=1e-6)
