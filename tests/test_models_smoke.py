"""Per-architecture smoke tests (brief requirement f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and finiteness.
Full configs are exercised only via the dry-run.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models.transformer import init_params, make_model
from repro.launch.train import make_train_step
from repro.optim.optimizer import cosine_schedule, make_optimizer

ARCHS = [a for a in list_archs() if a != "ibert-base"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = make_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s = 2, 16
    if cfg.frontend != "none":
        batch = {
            "embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                        jnp.float32),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
        logits = model.forward_logits(params, embeds=batch["embeds"])
    else:
        batch = {
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
        logits = model.forward_logits(params, tokens=batch["tokens"])
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt_init, opt_update = make_optimizer(
        "adamw", cosine_schedule(1e-3, 2, 10))
    step = jax.jit(make_train_step(model, opt_update))
    opt = opt_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually changed
    moved = jax.tree.map(
        lambda a, b2: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                            - b2.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_cell_applicability(arch):
    """Archs that skip long_500k must be pure full-attention; those that run
    it must be sub-quadratic (state-based decode)."""
    cfg = get_config(arch)
    kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
    if "long_500k" in cfg.skip_cells:
        assert kinds == {"attn"} and not cfg.local_window
    else:
        assert kinds != {"attn"} or cfg.local_window


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sane(arch):
    """Analytic param counts land in the family ballpark of the arch name."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "moonshot-v1-16b-a3b": (10e9, 40e9),
        "llama4-maverick-400b-a17b": (300e9, 480e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "phi3-medium-14b": (12e9, 16e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "minitron-8b": (7e9, 10e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "musicgen-medium": (1.2e9, 1.9e9),
        "internvl2-1b": (0.35e9, 0.8e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B"
    assert cfg.active_param_count() <= n


def test_reduced_configs_stay_in_family():
    for arch in ARCHS:
        cfg = get_config(arch)
        red = cfg.reduced()
        assert red.family == cfg.family
        assert (red.n_experts > 0) == (cfg.n_experts > 0)
        assert bool(red.block_pattern) == bool(cfg.block_pattern)
