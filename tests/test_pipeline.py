"""core/pipeline.pipelined_apply vs a sequential stage-by-stage reference.

GPipe schedule on a simulated `stage` mesh axis (subprocess: XLA_FLAGS must
be set before jax init).  Covers n_micro == n_stages, n_micro > n_stages,
and n_micro < n_stages, plus the Eq. 1 step-count arithmetic.
"""
import os
import subprocess
import sys
import textwrap


def _run(script: str, n_dev: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_pipelined_apply_matches_sequential_reference():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.pipeline import pipelined_apply, pipeline_steps
    from repro.launch.mesh import make_mesh

    n_stages, d = 4, 8
    mesh = make_mesh((n_stages,), ("stage",))
    rng = np.random.default_rng(0)
    # affine + nonlinearity per stage so stage order matters
    ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)).astype(np.float32))
    bs = jnp.asarray(rng.normal(0, 0.1, (n_stages, 1, d)).astype(np.float32))

    def stage_fn(p, x):
        w, b = p
        return jnp.tanh(x @ w + b)

    def reference(xm):
        out = xm
        for s in range(n_stages):
            out = jax.vmap(lambda v: stage_fn((ws[s], bs[s]), v))(out)
        return out

    for n_micro in (4, 6, 2):  # ==, >, < n_stages
        xm = jnp.asarray(
            rng.normal(0, 1, (n_micro, 3, d)).astype(np.float32))
        got = pipelined_apply(stage_fn, mesh, "stage", (ws, bs), xm)
        ref = reference(xm)
        assert got.shape == ref.shape, (n_micro, got.shape, ref.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        assert pipeline_steps(n_micro, n_stages) == n_micro + n_stages - 1
    print("PIPELINE-REF-OK")
    """)


def test_pipelined_apply_single_stage_degenerates():
    """n_stages=1: the schedule is just a per-microbatch map."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.pipeline import pipelined_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("stage",))
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.5, (1, 4, 4)).astype(np.float32))
    xm = jnp.asarray(rng.normal(0, 1, (3, 2, 4)).astype(np.float32))
    got = pipelined_apply(lambda p, v: v @ p, mesh, "stage", w, xm)
    ref = jnp.einsum("mbd,de->mbe", xm, w[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("PIPELINE-1STAGE-OK")
    """, n_dev=1)
