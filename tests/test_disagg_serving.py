"""Disaggregated prefill/decode pools (docs/serving.md §disaggregated
serving): the split admission queues and their pool-aware occupancy
signals are host-side (no devices); the page-shipping handoff itself runs
in a subprocess on a forced multi-device host platform (same pattern as
tests/test_throughput_serving.py) and is checked for bit-identity with
colocated serving plus the zero-transfer-on-hit contract.
"""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

import pytest


def _run(script: str, n_dev: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# -- scheduler: split queues (host-side) ------------------------------------


def _req(rid, n_prompt, budget=4, t=0.0):
    from repro.serving.scheduler import Request
    r = Request(rid=rid, prompt=np.zeros(n_prompt, np.int32),
                max_new_tokens=budget, t_arrival=t)
    r.t_enqueue = time.perf_counter()
    return r


def test_disagg_queue_depth_and_occupancy_split_by_pool():
    """With a classifier installed, queue_depth/projected_occupancy split
    per pool: hits count toward decode ingest (decode budget + un-hit
    suffix), colds toward the prefill pool (bucketed prompt cost).  The
    no-argument calls keep their combined historical meaning — the fleet
    router's Replica reads them unchanged."""
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(buckets=(16, 32, 64), deadline_s=60.0,
                      decode_horizon=8, max_batch=4)
    hits = {0: 16, 1: 0, 2: 48}  # rid -> advisory cached-prefix length
    cold = _req(1, 40, budget=8)
    sched.enqueue(_req(0, 20, budget=4))
    sched.enqueue(cold)
    sched.enqueue(_req(2, 60, budget=2))
    # without a classifier: everything owes prefill, decode queue empty
    assert sched.queue_depth() == 3
    assert sched.queue_depth("prefill") == 3
    assert sched.queue_depth("decode") == 0
    combined = sched.projected_occupancy()
    assert combined == (32 + 4) + (64 + 8) + (64 + 2)
    assert sched.projected_occupancy("prefill") == combined - (4 + 8 + 2)
    sched.set_disagg(lambda r: hits[r.rid])
    assert sched.queue_depth() == 3  # combined signal unchanged
    assert sched.queue_depth("prefill") == 1
    assert sched.queue_depth("decode") == 2
    # prefill pool owes only the cold prompt's bucket; the ingest side
    # owes every decode budget plus the hits' un-hit suffix re-ingest
    assert sched.projected_occupancy("prefill") == 64
    assert sched.projected_occupancy("decode") == \
        (4 + (20 - 16)) + 8 + (2 + (60 - 48))
    assert sched.projected_occupancy() == combined


def test_disagg_order_ingest_first_then_overdue_then_sjf():
    """Admission order under the split: decode-ingest hits first (FIFO,
    unlimited — they cost no prefill-pool or transfer work), then
    deadline-overdue colds FIFO, then at most `prefill_chunk` colds
    shortest-bucket-first, so a long-prompt burst cannot monopolize
    consecutive admission windows."""
    from repro.serving.scheduler import Scheduler

    sched = Scheduler(buckets=(16, 64), deadline_s=60.0, decode_horizon=8,
                      max_batch=8)
    hits = {0: 16, 3: 16}
    burst = [_req(1, 60), _req(2, 60)]         # cold, big bucket
    shorts = [_req(4, 10), _req(5, 10)]        # cold, small bucket
    overdue = _req(6, 60, t=-120.0)            # waited past the deadline
    sched.set_disagg(lambda r: hits.get(r.rid, 0), prefill_chunk=2)
    rest = [_req(0, 20)] + burst + [_req(3, 20)] + shorts + [overdue]
    # now=0.0 on the stream clock: fresh arrivals (t=0) have waited 0 s,
    # the overdue one (t=-120) is 120 s past the 60 s deadline
    order = sched._disagg_order(rest, now=0.0)
    rids = [r.rid for r in order]
    # hits FIFO, overdue FIFO, then 2 SJF colds — shorts jump the burst
    assert rids == [0, 3, 6, 4, 5]
    # chunk cap: raising it admits the burst colds too, SJF order
    sched.set_disagg(lambda r: hits.get(r.rid, 0), prefill_chunk=8)
    rids = [r.rid for r in sched._disagg_order(rest, now=0.0)]
    assert rids == [0, 3, 6, 4, 5, 1, 2]


# -- engine: validation -----------------------------------------------------


def test_disagg_engine_validation_errors():
    """disagg=(P, D) rejects the compositions that have no shipping
    story: dense slot rows, a ClusterPlan (it owns placement), a draft
    arena, and pool sizes the host platform can't satisfy."""
    out = _run("""
        import jax, numpy as np, pytest
        from repro.configs import get_config
        from repro.kernels import ops as kops
        from repro.models.transformer import init_params, make_model
        from repro.serving.engine import ContinuousBatchingEngine

        kops.set_impl("ref")
        cfg = get_config("smollm-135m").reduced()
        model = make_model(cfg, remat=False)
        params = init_params(cfg, jax.random.PRNGKey(0))
        kw = dict(max_batch=2, buckets=(16,), max_decode_len=8)
        with pytest.raises(ValueError, match="paged"):
            ContinuousBatchingEngine(model, params, paged=False,
                                     disagg=(1, 1), **kw)
        with pytest.raises(ValueError, match="spec_config"):
            ContinuousBatchingEngine(
                model, params, disagg=(1, 1),
                spec_config=dict(draft_model=model, draft_params=params,
                                 spec_k=2), **kw)
        with pytest.raises(ValueError, match="host devices"):
            ContinuousBatchingEngine(model, params, disagg=(2, 1), **kw)
        with pytest.raises(ValueError, match="host devices"):
            ContinuousBatchingEngine(model, params, disagg=(0, 2), **kw)
        print("VALIDATION-OK")
    """, n_dev=2)
    assert "VALIDATION-OK" in out


# -- engine: the handoff itself (multi-device subprocess) -------------------


def test_disagg_bit_identical_with_zero_transfer_hits():
    """The tentpole contract end-to-end on 2 forced host devices: the
    disaggregated engine's streams are bit-identical to colocated
    serving, every cold admission ships pages exactly once, a replay of
    the same prompts admits through the decode pool alone (prefix hits
    climb, shipped-page counters stay flat), and both pools' ledgers
    drain clean."""
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.kernels import ops as kops
        from repro.models.transformer import init_params, make_model
        from repro.serving.engine import ContinuousBatchingEngine
        from repro.serving.stream import bursty_requests, clone_requests

        kops.set_impl("ref")
        cfg = get_config("smollm-135m").reduced()
        model = make_model(cfg, remat=False)
        params = init_params(cfg, jax.random.PRNGKey(0))
        kw = dict(max_batch=2, buckets=(32, 64), max_decode_len=8,
                  num_pages=64, page_size=8)
        rng = np.random.default_rng(0)
        stream = bursty_requests(rng, 8, cfg.vocab_size,
                                 short_range=(10, 16), long_range=(40, 56),
                                 burst_every=3, burst_size=2,
                                 budgets=(3, 5))

        def serve(eng, reqs):
            for r in clone_requests(reqs):
                eng.submit(r)
            return {r.rid: tuple(r.tokens_out) for r in eng.run()}

        colo = ContinuousBatchingEngine(model, params, **kw)
        dis = ContinuousBatchingEngine(model, params, disagg=(1, 1), **kw)
        out_c, out_d = serve(colo, stream), serve(dis, stream)
        assert out_c == out_d, (out_c, out_d)
        assert dis.stats["prefills"] == len(stream)
        assert dis.stats["ship_dispatches"] == dis.stats["prefills"]
        assert dis.stats["shipped_pages"] > 0
        assert dis.stats["shipped_bytes"] > 0
        # replay the same prompts: radix-spanning hits — decode-side
        # admission only, ZERO page transfers, still bit-identical
        hits0 = dis.stats["prefix_hits"]
        ships0 = dis.stats["ship_dispatches"]
        out_c2, out_d2 = serve(colo, stream), serve(dis, stream)
        assert out_c2 == out_d2
        assert dis.stats["prefix_hits"] > hits0
        assert dis.stats["ship_dispatches"] == ships0
        # run() already drained both managers' ledgers (kv.assert_drained
        # + kv_prefill.assert_drained); re-check explicitly
        dis.kv.assert_drained()
        dis.kv_prefill.assert_drained()
        assert dis.kv_prefill.pages_in_use == 0
        print("DISAGG-OK hits=%d ships=%d"
              % (dis.stats["prefix_hits"], dis.stats["ship_dispatches"]))
    """, n_dev=2)
    assert "DISAGG-OK" in out
