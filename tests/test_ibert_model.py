"""Integer I-BERT encoder vs float oracle + no-padding equivalence (§7/§8)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ibert as ib


@pytest.fixture(scope="module")
def small_ibert():
    cfg = get_config("ibert-base")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=500, max_seq_len=64)
    key = jax.random.PRNGKey(0)
    params = ib.init_ibert_params(cfg, key)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    mask = jnp.ones((2, 24), bool).at[1, 16:].set(False)
    act = ib.calibrate(params, cfg, toks, mask)
    qp = ib.quantize_ibert(params, cfg, act)
    return cfg, params, qp, toks, mask


def test_integer_tracks_float(small_ibert):
    cfg, params, qp, toks, mask = small_ibert
    out_f = np.asarray(ib.ibert_float_forward(params, cfg, toks, mask))
    out_i = np.asarray(
        ib.ibert_int_forward(qp, cfg, toks, mask, impl="ref").dequantize())
    err = np.abs(out_i - out_f)
    assert err.max() < 0.5 * out_f.std()
    assert err.mean() < 0.1 * out_f.std()


def test_kernels_bit_exact_vs_ref(small_ibert):
    """The paper validates its FPGA encoder produces EXACTLY the software
    I-BERT outputs (§8.2); our Pallas kernels must match the jnp oracle."""
    cfg, params, qp, toks, mask = small_ibert
    a = ib.ibert_int_forward(qp, cfg, toks, mask, impl="ref")
    b = ib.ibert_int_forward(qp, cfg, toks, mask, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))


def test_no_padding_equivalence(small_ibert):
    """Paper §7.1: running a short sequence unpadded == running it padded
    with masking (bit-identical per-token outputs), so the latency saving
    is free."""
    cfg, params, qp, toks, mask = small_ibert
    short = toks[:1, :10]
    # unpadded run
    out_short = ib.ibert_int_forward(
        qp, cfg, short, jnp.ones((1, 10), bool), impl="ref")
    # padded-with-mask run
    padded = jnp.zeros((1, 24), short.dtype).at[:, :10].set(short)
    pmask = jnp.zeros((1, 24), bool).at[:, :10].set(True)
    out_pad = ib.ibert_int_forward(qp, cfg, padded, pmask, impl="ref")
    a = np.asarray(out_short.dequantize())
    b = np.asarray(out_pad.dequantize())[:, :10]
    # requant stats differ slightly (dynamic shift on masked scores is
    # identical by construction of static scales) -> allow tiny tolerance
    assert np.abs(a - b).max() < 0.05


def test_calibration_covers_all_sites(small_ibert):
    cfg, params, qp, toks, mask = small_ibert
    act = qp["act"]
    for i in range(cfg.n_layers):
        for site in ("q", "k", "v", "scores", "ctx", "attn", "res1", "ln1",
                     "ff1", "gelu", "ff2", "res2", "ln2"):
            assert f"L{i}.{site}" in act
    assert all(float(v) > 0 for v in act.values())
