"""Throughput-mode serving (exact=False plans): psum-form TP specs, the
request-skewed pipeline schedule, lane-group scheduling, and the
stage-local KV accounting behind it.

The exactness contract (docs/serving.md): exact plans stay bit-identical
and their tests (tests/test_sharded_serving.py) are untouched; throughput
plans are gated by a token-match band (>=0.98) instead of equality.  The
multi-device case runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
tests/test_pipeline.py); everything else is host-side (scheduler /
kv-manager / spec rules) and needs no devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

import pytest


def _run(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# -- scheduler: lane groups ------------------------------------------------


def test_lane_groups_partition_and_admission_balance():
    """Lanes partition into equal contiguous groups, and `order_free`
    round-robins a burst of admissions across the emptiest groups instead
    of packing the first group solid."""
    from repro.serving.scheduler import Request, Scheduler

    s = Scheduler((16,), 0.0, decode_horizon=8, max_batch=8)
    s.set_lane_groups(4)
    groups = {g: [i for i in range(8) if s.lane_group(i) == g]
              for g in range(4)}
    assert groups == {0: [0, 1], 1: [2, 3], 2: [4, 5], 3: [6, 7]}
    assert sorted(sum(groups.values(), [])) == list(range(8))  # disjoint

    # group 0 fully occupied, group 2 half occupied, groups 1/3 empty:
    # the free-slot order must visit every emptier group before giving
    # group 2 a second occupant, and group 0 has nothing free at all
    r = Request(rid=0, prompt=np.zeros(2, np.int32))
    slots = [r, r, None, None, r, None, None, None]
    free = s.order_free([i for i, x in enumerate(slots) if x is None],
                        slots)
    assert free[:2] == [2, 6]  # first pass: one slot per empty group
    assert free[2] == 3 or free[2] == 7 or free[2] == 5
    assert sorted(free) == [2, 3, 5, 6, 7]  # a permutation, nothing lost
    # a burst into an empty batch round-robins all four groups first
    free = s.order_free(list(range(8)), [None] * 8)
    assert free[:4] == [0, 2, 4, 6]
    assert free[4:] == [1, 3, 5, 7]
    # degenerate single group: order untouched
    s2 = Scheduler((16,), 0.0, 8, 8)
    assert s2.order_free([3, 1, 2], [None] * 8) == [3, 1, 2]
    # indivisible partitions are rejected
    with pytest.raises(AssertionError):
        s.set_lane_groups(3)


def test_lane_groups_under_admission_preemption_churn():
    """Drive the real admission cycle with completions and preemptions:
    admissions always land on the group-balanced prefix of the free list,
    no lane starves, and the drain terminates."""
    from repro.serving.scheduler import Request, Scheduler

    rng = np.random.default_rng(0)
    s = Scheduler((16,), 0.05, decode_horizon=8, max_batch=8)
    s.set_lane_groups(4)
    reqs = [Request(rid=i, prompt=np.zeros(3, np.int32),
                    max_new_tokens=int(rng.integers(1, 6)),
                    t_arrival=i * 0.001) for i in range(40)]
    pending = list(reqs)
    slots = [None] * 8
    done, lanes_used = [], set()
    now, steps = 0.0, 0
    while (pending or any(x is not None for x in slots)) and steps < 2000:
        steps += 1
        now += 0.01
        free = s.order_free([i for i, x in enumerate(slots) if x is None],
                            slots)
        admitted, _ = s.admission_cycle(pending, list(free), now, (),
                                        lambda r, sl: True)
        # admission_cycle pops the ordered free list front-to-back, so the
        # slots it filled must be exactly the balanced prefix
        assert [sl for _, sl in admitted] == free[:len(admitted)]
        for r, sl in admitted:
            pending.remove(r)
            slots[sl] = r
            lanes_used.add(sl)
        for i, r in enumerate(slots):  # one decode step per occupied lane
            if r is None:
                continue
            r.append_token(7, now)
            if r.done:
                done.append(r)
                slots[i] = None
        if steps % 5 == 0:  # periodic pool-pressure preemption
            v = s.victim(slots)
            if v is not None:
                r = slots[v]
                r.n_preempts += 1
                slots[v] = None
                pending.append(r)
    assert steps < 2000, "drain did not terminate"
    assert len(done) == len(reqs) and all(r.done for r in reqs)
    assert lanes_used == set(range(8)), f"starved lanes: " \
        f"{set(range(8)) - lanes_used}"


# -- kv-manager: per-shard residency + stage views -------------------------


def test_kv_page_bytes_stage_sharding():
    """A stage-sharded arena page costs 1/shards of the layer stack per
    device — but ONLY when the stack divides; otherwise the arena
    replicates and a page costs its full span everywhere."""
    import dataclasses

    from repro.configs import get_config
    from repro.serving.kv_manager import kv_page_bytes, num_pages_for_hbm

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=8)
    full = kv_page_bytes(cfg, 16, "bf16")
    assert kv_page_bytes(cfg, 16, "bf16", shards=4) == full // 4
    assert kv_page_bytes(cfg, 16, "int8", shards=8) \
        == kv_page_bytes(cfg, 16, "int8") // 8
    # 8 layers over 3 stages don't divide: replicated, full cost
    assert kv_page_bytes(cfg, 16, "bf16", shards=3) == full
    budget = 64 * full
    assert num_pages_for_hbm(cfg, 16, "bf16", budget) == 64
    assert num_pages_for_hbm(cfg, 16, "bf16", budget, shards=4) == 256
    assert num_pages_for_hbm(cfg, 16, "bf16", budget, shards=3) == 64


def test_kv_manager_per_shard_ledger_tracks_actual_frees():
    """The per-shard residency ledger moves by the pages each operation
    ACTUALLY freed (shared prefix pages stay resident through a decref),
    stage views report stage-local bytes, and `assert_drained`
    cross-checks every shard against the pool — no cross-stage leaks."""
    import dataclasses

    from repro.configs import get_config
    from repro.serving.kv_manager import KVManager, kv_page_bytes

    kv = KVManager(num_pages=9, page_size=4, max_batch=4, max_pages=8,
                   shards=4)
    prompt = np.arange(11, dtype=np.int32)
    g = kv.admit(prompt, rem_budget=5, max_hit_suffix=16)  # 16 pos -> 4 pg
    assert g is not None and g.hit_len == 0
    kv.commit(0, g)
    kv.register_prefix(prompt, g.pages)
    assert (kv._shard_pages == kv.pool.pages_in_use).all()
    # a hit shares the 2 full prefix pages: only the remainder is new,
    # and every shard's ledger moves by the same (actual) amount
    before = kv.shard_pages_in_use()
    g2 = kv.admit(prompt, rem_budget=5, max_hit_suffix=16)
    assert g2.hit_len == 8 and g2.pages[:2] == g.pages[:2]
    kv.commit(1, g2)
    grew = kv.shard_pages_in_use() - before
    assert grew == len(g2.pages) - len(g2.hit_pages)
    assert (kv._shard_pages == kv.pool.pages_in_use).all()
    # release lane 1: the shared prefix pages are still held by lane 0 +
    # the tree, so the ledger drops by the exclusively-owned pages only
    before = kv.shard_pages_in_use()
    kv.release(1)
    assert before - kv.shard_pages_in_use() == grew
    assert (kv._shard_pages == kv.pool.pages_in_use).all()
    kv.release(0)
    # only tree references remain; every shard agrees with the pool
    kv.assert_drained()
    # stage views: stage-local byte accounting at 1/shards per page
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=8)
    v = kv.stage_view(2)
    assert v.pages_in_use == kv.pool.pages_in_use
    assert v.resident_bytes(cfg) == v.pages_in_use * kv_page_bytes(
        cfg, 4, "bf16", shards=4)
    # eviction under pool pressure frees tree pages on EVERY shard; the
    # declined prefix hit (suffix > max_hit_suffix) also exercises the
    # actual-frees rule — its decref frees nothing (tree refs remain)
    big = kv.admit(np.arange(28, dtype=np.int32), rem_budget=0,
                   max_hit_suffix=0)  # 7 pages > 6 free: tree must evict
    assert big is not None
    assert (kv._shard_pages == kv.pool.pages_in_use).all()
    kv.commit(3, big)
    kv.release(3)
    kv.assert_drained()
    # a cross-stage leak (one shard's slab stranded) fails loudly
    kv._shard_pages[1] += 1
    with pytest.raises(AssertionError):
        kv.assert_drained()


def test_evict_cached_moves_every_shard_ledger_by_actual_frees():
    """`evict_cached` is the only correct external eviction path: it
    routes the pages the tree ACTUALLY freed through the per-shard
    residency ledger.  Lane-held prefix pages are not evictable (the
    tree's LRU only frees tree-only leaves), so the returned count can
    undershoot the request — and the ledger must move by that count on
    every shard, never by the requested figure."""
    import dataclasses

    from repro.configs import get_config
    from repro.serving.kv_manager import KVManager, kv_page_bytes

    kv = KVManager(num_pages=9, page_size=4, max_batch=4, max_pages=8,
                   shards=4)
    held = np.arange(8, dtype=np.int32)        # 2 full pages, stays laned
    g1 = kv.admit(held, rem_budget=0, max_hit_suffix=16)
    kv.commit(0, g1)
    kv.register_prefix(held, g1.pages)
    idle = np.arange(100, 108, dtype=np.int32)  # 2 full pages, tree-only
    g2 = kv.admit(idle, rem_budget=0, max_hit_suffix=16)
    kv.commit(1, g2)
    kv.register_prefix(idle, g2.pages)
    kv.release(1)
    before = kv.shard_pages_in_use(0)
    # ask for 4: only `idle`'s 2 pages are evictable (lane 0 still holds
    # `held`'s, so the tree drops at most its own leaf refs there)
    freed = kv.evict_cached(4)
    assert freed == len(g2.pages) == 2
    for shard in range(kv.shards):
        assert before - kv.shard_pages_in_use(shard) == freed
    assert (kv._shard_pages == kv.pool.pages_in_use).all()
    # stage views observe the eviction in stage-local bytes
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=8)
    v = kv.stage_view(3)
    assert v.pages_in_use == kv.pool.pages_in_use
    assert v.resident_bytes(cfg) == v.pages_in_use * kv_page_bytes(
        cfg, 4, "bf16", shards=4)
    # the evicted prefix is really gone (cold again), the held one hits
    assert kv.peek_hit(np.arange(100, 109, dtype=np.int32)) == 0
    assert kv.peek_hit(np.arange(9, dtype=np.int32)) == 8
    # drain: release the held lane, evict the remainder, ledgers at zero
    kv.release(0)
    assert kv.evict_cached(kv.pool.num_pages) == len(g1.pages)
    assert kv.pool.pages_in_use == 0
    assert (kv._shard_pages == 0).all()
    kv.assert_drained()


# -- cluster-builder: the exact flag ---------------------------------------


def test_serve_param_specs_psum_form_when_not_exact():
    """exact=True serve plans replicate the reduction projections
    (gather-form TP, bit-identical); exact=False column-shards them over
    `model` — Megatron psum-form (spec-only, abstract mesh)."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_abstract_mesh
    from repro.models.transformer import init_params, make_model

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_heads=8, n_kv_heads=8)
    make_model(cfg, remat=False)
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    mesh = make_abstract_mesh((1, 8), ("data", "model"))
    exact = build_plan(cfg, mesh, mode="serve")
    assert exact.exact
    es = exact.specs_for_params(params_shape)
    assert all(p is None for p in es["scan"]["b0"]["mix"]["wo"])
    assert all(p is None for p in es["scan"]["b0"]["ffn"]["wo"])
    psum = build_plan(cfg, mesh, mode="serve", exact=False)
    assert not psum.exact
    ps = psum.specs_for_params(params_shape)
    # scan leaves are (n_rep, in, out): column dim (in) shards over model
    assert ps["scan"]["b0"]["mix"]["wo"][1] == "model"
    assert ps["scan"]["b0"]["ffn"]["wo"][1] == "model"
    # non-reduction projections keep the same row sharding either way
    assert ps["scan"]["b0"]["mix"]["wq"] == es["scan"]["b0"]["mix"]["wq"]
    # throughput serve_pipeline: paged arena leaves shard over `stage`
    from repro.models.transformer import make_model as _mm
    pcfg = dataclasses.replace(cfg, n_layers=4)
    model = _mm(pcfg, remat=False)
    smesh = make_abstract_mesh((4,), ("stage",))
    skew = build_plan(pcfg, smesh, mode="serve_pipeline", exact=False)
    shape = jax.eval_shape(
        lambda: model.init_paged_cache(4, 32, 8, 8))
    specs = skew.specs_for_caches(shape, batch=4, paged=True)
    assert specs["scan"]["b0"]["k"][0] == "stage"
    assert specs["scan"]["b0"]["v"][0] == "stage"
    assert all(p is None for p in specs["pt"])  # shared routing metadata
    assert all(p is None for p in specs["pos"])


def test_paged_eligible_throughput_pipeline():
    """The paged predicate: exact serve_pipeline streams the dense slot
    path; the throughput (exact=False) plan decodes from stage-local
    paged arenas."""
    import dataclasses

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_abstract_mesh
    from repro.serving.kv_manager import paged_eligible

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=4)
    mesh = make_abstract_mesh((4,), ("stage",))
    assert not paged_eligible(cfg, build_plan(cfg, mesh,
                                              mode="serve_pipeline"))
    assert paged_eligible(cfg, build_plan(cfg, mesh, mode="serve_pipeline",
                                          exact=False))


# -- tentpole: the request-skewed schedule (8 host devices) ----------------


def test_skewed_pipeline_streams_within_match_band():
    """exact=False serve_pipeline on an 8-stage mesh: the request-skewed
    engine's streams match the plan-free paged engine's within the
    exactness contract's 0.98 band (with the pinned ref kernels they are
    in fact identical), lane groups are active, and the stage-local
    arenas drain leak-free."""
    _run("""
    import dataclasses
    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine, Request

    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=8)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((8,), ("stage",))
    plan = build_plan(cfg, mesh, mode="serve_pipeline", exact=False)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, k).astype(np.int32)
               for k in (5, 9, 12, 6, 8, 11, 7, 10, 4, 13)]
    budgets = [3, 8, 5, 6, 4, 7, 2, 9, 5, 6]

    def run(plan_, **kw):
        eng = ContinuousBatchingEngine(model, params, max_batch=8,
                                       buckets=(16,), plan=plan_,
                                       page_size=8, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=budgets[i]))
        return {r.rid: r.tokens_out for r in eng.run()}, eng

    with kops.pinned_impl("ref"):
        ref, _ = run(None)
        skew, eng = run(plan)
    assert eng.paged, "throughput pipeline must serve from the paged arena"
    assert eng.sched.n_lane_groups == 8, eng.sched.n_lane_groups
    assert eng.kv.shards == 8
    # run() already called kv.assert_drained(): per-stage ledgers agree
    tot = sum(len(v) for v in ref.values())
    matched = sum(sum(a == b for a, b in zip(ref[r], skew[r])) for r in ref)
    rate = matched / tot
    assert rate >= 0.98, (rate, ref, skew)
    print(f"SKEW-MATCH {matched}/{tot}")
    """)


def test_skewed_pipeline_rejects_spec_config():
    """Speculative decoding has no skewed-schedule program: composing it
    with a throughput serve_pipeline plan must fail loudly at
    construction, not decode garbage."""
    _run("""
    import dataclasses
    import jax

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=4)
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    draft = make_model(dcfg, remat=False)
    dparams = init_params(dcfg, jax.random.PRNGKey(1))
    plan = build_plan(cfg, make_mesh((4,), ("stage",)),
                      mode="serve_pipeline", exact=False)
    try:
        ContinuousBatchingEngine(
            model, params, max_batch=4, buckets=(16,), plan=plan,
            spec_config=dict(draft_model=draft, draft_params=dparams,
                             spec_k=4))
    except ValueError as e:
        assert "spec_config" in str(e)
        print("SPEC-REJECT-OK")
    else:
        raise AssertionError("skewed plan + spec_config must raise")
    """, n_dev=4)


def test_serve_dryrun_prints_exactness_modes():
    """launch/serve.py --no-exact --dryrun: the header carries the exact
    flag and every plan leaf is annotated with its exactness mode."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--plan", "serve", "--mesh", "1,8",
         "--no-exact", "--dryrun"], capture_output=True, text=True,
        env=env, timeout=300)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "exact=False" in out.stdout
    assert "[psum(throughput)]" in out.stdout  # the reduction projections
    assert "[exact]" in out.stdout  # everything else
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--plan", "serve_pipeline", "--mesh",
         "2", "--no-exact", "--dryrun"], capture_output=True, text=True,
        env=env, timeout=300)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "exact=False" in out.stdout
    assert "[skewed(throughput)]" in out.stdout
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--plan", "serve", "--mesh", "1,8",
         "--dryrun"], capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "exact=True" in out.stdout
    assert "[gather(exact)]" in out.stdout
