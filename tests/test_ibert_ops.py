"""Property tests (hypothesis) for the integer-only I-BERT math.

Bounds mirror I-BERT's published approximation errors: i-exp <= ~3e-3,
i-GELU <= ~2e-2 absolute, i-softmax rows sum to 1 within quant resolution.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback draws (see detshim.py)
    from detshim import given, settings
    import detshim as st

import jax.numpy as jnp

from repro.core import ibert_ops as iops
from repro.core.quant import quantize

_settings = dict(max_examples=30, deadline=None)


@given(st.lists(st.floats(-30.0, 0.0), min_size=4, max_size=200),
       st.floats(0.6, 40.0))
@settings(**_settings)
def test_i_exp_error_bound(vals, amax):
    x = np.asarray(vals, np.float32)
    q = quantize(jnp.asarray(x), scale=jnp.float32(amax / iops.ACT_QMAX),
                 bits=iops.ACT_BITS)
    qe, se = iops.i_exp(q.values.astype(jnp.int32), q.scale)
    approx = np.asarray(qe, np.float64) * float(se)
    exact = np.exp(np.asarray(q.values, np.float64) * float(q.scale))
    assert np.all(np.asarray(qe) >= 0)
    # poly error (~3e-3, I-BERT Fig.2) + one quantization step of slack
    assert np.abs(approx - exact).max() < 5e-3 + float(q.scale)


@given(st.integers(0, 2**31 - 1))
@settings(**_settings)
def test_i_sqrt_close(n):
    got = int(iops.i_sqrt(jnp.asarray([n], jnp.int32))[0])
    exact = int(np.sqrt(n))
    # I-BERT early-stop Newton can land 1 off the exact floor
    assert abs(got - exact) <= 1


@given(st.integers(2, 8), st.integers(4, 96), st.floats(0.6, 20.0),
       st.integers(0, 10_000))
@settings(**_settings)
def test_i_softmax_distribution(rows, cols, spread, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, spread, (rows, cols)).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=iops.ACT_BITS)
    qp, sp = iops.i_softmax(q.values.astype(jnp.int32), q.scale)
    p = np.asarray(qp) * float(sp)
    assert np.all(p >= 0)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=2e-2)
    ref = np.asarray(iops.f_softmax(jnp.asarray(x)))
    assert np.abs(p - ref).max() < 0.02
    # ordering preserved within quantization resolution
    for r in range(rows):
        top_i, top_ref = np.argmax(p[r]), np.argmax(ref[r])
        assert p[r, top_i] >= p[r, top_ref] - 2 ** -iops.SOFTMAX_OUT_BITS


@given(st.floats(0.6, 30.0), st.integers(0, 10_000))
@settings(**_settings)
def test_i_gelu_error_bound(amax, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-amax, amax, 500).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=iops.ACT_BITS)
    qq = iops.requantize_to_bits(q.values.astype(jnp.int32), q.scale)
    g, sg = iops.i_gelu(qq.values, qq.scale)
    approx = np.asarray(g, np.float64) * float(sg)
    ref = np.asarray(iops.f_gelu(qq.values.astype(jnp.float32) * qq.scale))
    # I-BERT reports ~1.8e-2 max abs error for i-GELU
    assert np.abs(approx - ref).max() < 0.03


@given(st.integers(2, 6), st.sampled_from([64, 768, 1024]),
       st.integers(0, 10_000))
@settings(**_settings)
def test_i_layernorm_error(rows, h, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 2, (rows, h)).astype(np.float32)
    gamma = rng.uniform(0.5, 1.5, h).astype(np.float32)
    beta = rng.normal(0, 0.2, h).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=8)
    prep = iops.layernorm_prepare(jnp.asarray(gamma), jnp.asarray(beta))
    qy, sy = iops.i_layernorm(q.values.astype(jnp.int32), prep)
    y = np.asarray(qy) * float(sy)
    ref = np.asarray(iops.f_layernorm(jnp.asarray(x), gamma, beta))
    # int8 input quantization dominates the error budget
    assert np.abs(y - ref).max() < 0.15
    assert np.abs(y - ref).mean() < 0.04


# -- deterministic cases (run with or without hypothesis) --------------------


@pytest.mark.parametrize("n", [0, 1, 2, 3, 15, 16, 10 ** 6, 2 ** 31 - 1])
def test_i_sqrt_exact_values(n):
    got = int(iops.i_sqrt(jnp.asarray([n], jnp.int32))[0])
    assert abs(got - int(np.sqrt(n))) <= 1


def test_i_exp_fixed_grid():
    x = np.linspace(-30.0, 0.0, 64).astype(np.float32)
    q = quantize(jnp.asarray(x), scale=jnp.float32(30.0 / iops.ACT_QMAX),
                 bits=iops.ACT_BITS)
    qe, se = iops.i_exp(q.values.astype(jnp.int32), q.scale)
    approx = np.asarray(qe, np.float64) * float(se)
    exact = np.exp(np.asarray(q.values, np.float64) * float(q.scale))
    assert np.all(np.asarray(qe) >= 0)
    assert np.abs(approx - exact).max() < 5e-3 + float(q.scale)


def test_i_softmax_fixed_rows():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 4.0, (4, 48)).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=iops.ACT_BITS)
    qp, sp = iops.i_softmax(q.values.astype(jnp.int32), q.scale)
    p = np.asarray(qp) * float(sp)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=2e-2)
    ref = np.asarray(iops.f_softmax(jnp.asarray(x)))
    assert np.abs(p - ref).max() < 0.02


def test_i_layernorm_fixed_case():
    rng = np.random.default_rng(11)
    x = rng.normal(0, 2, (4, 768)).astype(np.float32)
    gamma = rng.uniform(0.5, 1.5, 768).astype(np.float32)
    beta = rng.normal(0, 0.2, 768).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=8)
    prep = iops.layernorm_prepare(jnp.asarray(gamma), jnp.asarray(beta))
    qy, sy = iops.i_layernorm(q.values.astype(jnp.int32), prep)
    y = np.asarray(qy) * float(sy)
    ref = np.asarray(iops.f_layernorm(jnp.asarray(x), gamma, beta))
    assert np.abs(y - ref).max() < 0.15


def test_i_gelu_monotone_region():
    """GELU is monotone for x > ~0.4; the integer poly must preserve it up
    to floor-rounding (the >>g renormalization can dip by one phi-LSB)."""
    x = np.linspace(0.5, 8.0, 400).astype(np.float32)
    q = quantize(jnp.asarray(x), bits=iops.ACT_BITS)
    g, sg = iops.i_gelu(q.values.astype(jnp.int32), q.scale)
    deq = np.asarray(g, np.float64) * float(sg)
    span = deq.max() - deq.min()
    assert np.all(np.diff(deq) >= -0.005 * span)
    # and globally increasing: endpoint ordering strictly preserved
    assert deq[-1] > deq[0]
    assert np.corrcoef(deq, x)[0, 1] > 0.999
