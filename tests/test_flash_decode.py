"""Split-KV flash-decode kernel vs jnp oracle + fused multi-step decode.

Kernel bar: interpret-mode (real Pallas body) vs ref-oracle equality across
GQA ratios, KV split counts, sliding windows, kpos-sentinel rows and active
masks.  Model bar: `decode_steps(n=k)` token streams are bit-identical to k
chained `decode_step` calls (the engine acceptance invariant), under both
the oracle and the kernel impls.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops

SENTINEL = 2 ** 30


def _mk(b, h, kvh, hd, s, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd)), dtype) * (hd ** -0.5)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kvh, hd)), dtype)
    kpos = jnp.asarray(np.tile(np.arange(s), (b, 1)), jnp.int32)
    qpos = jnp.asarray(rng.integers(s // 2, s, b), jnp.int32)
    return q, k, v, kpos, qpos


def _both(q, k, v, kpos, qpos, **kw):
    got = ops.flash_decode(q, k, v, kpos, qpos, impl="interpret", **kw)
    kw.pop("bs", None)
    want = ops.flash_decode(q, k, v, kpos, qpos, impl="ref", **kw)
    return np.asarray(got), np.asarray(want)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 1), (9, 3)])
def test_flash_decode_gqa_ratios(h, kvh):
    q, k, v, kpos, qpos = _mk(2, h, kvh, 16, 64, seed=h)
    got, want = _both(q, k, v, kpos, qpos)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s,bs", [(33, 8), (64, 16), (256, 64), (96, 96)])
def test_flash_decode_split_counts(s, bs):
    """Multi-split online-softmax partials == one-shot softmax oracle."""
    q, k, v, kpos, qpos = _mk(2, 4, 2, 16, s, seed=s)
    got, want = _both(q, k, v, kpos, qpos, bs=bs)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 16, 40])
def test_flash_decode_sliding_window(window):
    q, k, v, kpos, qpos = _mk(3, 4, 2, 16, 48, seed=window)
    got, want = _both(q, k, v, kpos, qpos, window=window, bs=16)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_decode_ring_buffer_positions():
    """Window cache as the serving ring buffer: kpos is absolute position
    at slot = pos % window, exactly as models/attention.py writes it."""
    window, s = 16, 16
    q, k, v, _, _ = _mk(2, 4, 2, 16, s, seed=9)
    qpos = jnp.asarray([20, 7], jnp.int32)
    kpos = jnp.stack([20 - ((20 - jnp.arange(s)) % s),
                      jnp.where(jnp.arange(s) <= 7, jnp.arange(s),
                                SENTINEL)]).astype(jnp.int32)
    got, want = _both(q, k, v, kpos, qpos, window=window, bs=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_decode_kpos_sentinel_rows():
    """Never-written slots (2^30) are unreachable; a fully-sentinel row
    (fresh slot) yields exact zeros, not NaN."""
    q, k, v, kpos, qpos = _mk(3, 4, 2, 16, 32, seed=3)
    kpos = kpos.at[0, 10:].set(SENTINEL)
    kpos = kpos.at[1].set(SENTINEL)
    got, want = _both(q, k, v, kpos, qpos, bs=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got[1], 0.0)


def test_flash_decode_active_mask():
    """Inactive slots produce exact zeros in both impls; active rows are
    untouched by their neighbours' masking."""
    q, k, v, kpos, qpos = _mk(4, 4, 2, 16, 32, seed=5)
    active = jnp.asarray([True, False, True, False])
    got, want = _both(q, k, v, kpos, qpos, active=active, bs=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(got[1], 0.0)
    np.testing.assert_array_equal(got[3], 0.0)
    all_on, _ = _both(q, k, v, kpos, qpos, bs=8)
    np.testing.assert_array_equal(got[0], all_on[0])


def test_flash_decode_bf16():
    q, k, v, kpos, qpos = _mk(2, 4, 2, 32, 64, seed=11, dtype=jnp.bfloat16)
    got, want = _both(q, k, v, kpos, qpos, bs=16)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_decode_matches_dense_decode_path():
    """Cross-check against the model's masked dense decode formulation."""
    from repro.models import attention as am

    b, s, h, kvh, hd = 2, 40, 4, 2, 16
    q, k, v, kpos, qpos = _mk(b, h, kvh, hd, s, seed=13)
    msk = am._mask(1, s, qpos[:, None], kpos, True, 0)
    dense = am._dense_attention(q[:, None], k, v, msk)[:, 0]
    got = ops.flash_decode(q, k, v, kpos, qpos, impl="interpret", bs=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# fused multi-step decode (Model.decode_steps)
# ---------------------------------------------------------------------------


def _model_setup(arch="smollm-135m"):
    from repro.configs import get_config
    from repro.models.transformer import init_params, make_model

    cfg = get_config(arch).reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_decode_steps_equals_chained_decode_step(arch, impl):
    """decode_steps(n=k) == k chained decode_step calls, bit-identical,
    including mid-scan EOS and budget early-exit masking."""
    from repro.kernels import ops as kops

    prev = kops._IMPL
    kops.set_impl(impl)
    try:
        cfg, model, params = _model_setup(arch)
        b, k = 3, 6
        rng = np.random.default_rng(17)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 5)),
                           jnp.int32)
        caches = model.init_cache(b, 32)
        logits, caches = model.prefill(params, caches, tokens=toks)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        active = jnp.asarray([True, True, False])
        budget = jnp.asarray([k, 3, k], jnp.int32)  # lane 1 exits mid-scan
        eos = jnp.full((b,), -1, jnp.int32)

        fused, cur_f, act_f, rem_f, _ = model.decode_steps(
            params, caches, cur, active, k, eos_id=eos, budget=budget)

        # chained reference with identical host-side masking
        c, a, r = cur, active, budget
        chain = []
        for _ in range(k):
            lg, caches = model.decode_step(params, caches, c, active=a)
            nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            chain.append(np.where(np.asarray(a), np.asarray(nxt), -1))
            r = jnp.where(a, r - 1, r)
            a = a & (nxt != eos) & (r > 0)
            c = jnp.where(a, nxt, 0).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(fused), np.stack(chain))
        np.testing.assert_array_equal(np.asarray(cur_f), np.asarray(c))
        np.testing.assert_array_equal(np.asarray(act_f), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(rem_f), np.asarray(r))
        # lane 1 emitted exactly its budget then went dark
        col = np.asarray(fused)[:, 1]
        assert (col[:3] >= 0).all() and (col[3:] == -1).all()
        # inactive lane 2 never emitted
        assert (np.asarray(fused)[:, 2] == -1).all()
    finally:
        kops._IMPL = prev
