"""Cluster Builder + clusters-of-clusters invariants (paper §4, §6)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.core import cluster as cl
from repro.core.cluster_builder import build_plan, build_topology
from repro.launch.mesh import make_abstract_mesh
from repro.models.transformer import init_params, make_model

ARCHS = [a for a in list_archs() if a != "ibert-base"]


def _mesh(shape=(16, 16), axes=("data", "model")):
    return make_abstract_mesh(shape, axes)


# -- topology (paper-faithful bookkeeping) -----------------------------------


def test_ibert_encoder_cluster_matches_fig14():
    """Paper §9.4: 'we have 38 kernels, including six GMI kernels' per
    encoder cluster (Fig. 14 numbers kernels 0..38 skipping 33): kern_0
    gateway/broadcast, 1-3 linear+quant, 4-15 dotprod+softmax, 16-27
    softmax-matmul, then linear/LN/FFN/LN + scatter/gather/broadcast."""
    cfg = get_config("ibert-base")
    topo = build_topology(cfg)
    assert len(topo.clusters) == cfg.n_layers == 12
    c = topo.clusters[0]
    assert len(c.kernels) == 38
    assert c.kernels[0].kind == "gateway"
    assert [k.op for k in c.kernels[1:4]] == [
        "linear_q_quant", "linear_k_quant", "linear_v_quant"]
    assert all(k.op == "dotprod_softmax" for k in c.kernels[4:16])
    assert all(k.op == "softmax_matmul_quant" for k in c.kernels[16:28])
    comm = sum(1 for k in c.kernels if k.kind in ("gmi", "gateway"))
    assert comm == 6  # six GMI/communication kernels (paper §9.4)


@pytest.mark.parametrize("arch", ARCHS)
def test_topology_within_galapagos_limits(arch):
    topo = build_topology(get_config(arch))
    topo.validate()
    for c in topo.clusters:
        assert len(c.kernels) <= cl.MAX_KERNELS_PER_CLUSTER
        ids = [k.local_id for k in c.kernels]
        assert ids == sorted(ids) == list(range(len(ids)))
    assert len(topo.clusters) <= cl.MAX_CLUSTERS


def test_gateway_routing_table_arithmetic():
    """Paper §4: gateways cut per-device routes from ~N^2 to 2N-1."""
    topo = build_topology(get_config("deepseek-coder-33b"))
    n_clusters = len(topo.clusters)
    per_cluster = max(len(c.kernels) for c in topo.clusters)
    with_gw = topo.routing_entries_per_device()
    flat = topo.routing_entries_flat()
    assert with_gw == per_cluster + n_clusters - 1
    assert flat > with_gw  # the paper's saving
    assert cl.max_addressable_kernels() == 65536


def test_inter_cluster_edges_go_through_gateway():
    topo = build_topology(get_config("smollm-135m"))
    for (sc, sl), (dc, dl) in topo.edges:
        if sc != dc:
            assert dl == cl.GATEWAY_LOCAL_ID or sl == cl.GATEWAY_LOCAL_ID


def test_cluster_kernel_limit_enforced():
    topo = cl.ClusterTopology()
    c = topo.new_cluster()
    for _ in range(cl.MAX_KERNELS_PER_CLUSTER - 1):
        c.add("compute")
    with pytest.raises(ValueError):
        c.add("compute")


# -- sharding plan ------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    """Every assigned spec axis must divide its dim (no silent padding)."""
    cfg = get_config(arch)
    mesh = _mesh((2, 16, 16), ("pod", "data", "model")) if multi_pod \
        else _mesh()
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    plan = build_plan(cfg, mesh, params_shape, batch=256)

    def check(path, spec, shape):
        for i, part in enumerate(spec):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert shape[i] % n == 0, (path, shape, spec)

    def walk(specs, shapes, path=()):
        if isinstance(specs, dict):
            for k in specs:
                walk(specs[k], shapes[k], path + (k,))
        else:
            check(path, specs, shapes.shape)

    walk(plan.param_specs, params_shape)


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "moonshot-v1-16b-a3b"])
def test_big_weights_are_sharded(arch):
    """>=2D weights above 1M elements must not be fully replicated."""
    cfg = get_config(arch)
    mesh = _mesh()
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    plan = build_plan(cfg, mesh, params_shape, batch=256)

    def walk(specs, shapes, path=()):
        if isinstance(specs, dict):
            for k in specs:
                walk(specs[k], shapes[k], path + (k,))
        else:
            size = int(np.prod(shapes.shape))
            if size > 4_000_000 and path[-1] not in ("r", "w_in"):
                assert any(p is not None for p in specs), (path, shapes.shape)

    walk(plan.param_specs, params_shape)


def test_moe_experts_on_model_axis():
    cfg = get_config("moonshot-v1-16b-a3b")
    mesh = _mesh()
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    plan = build_plan(cfg, mesh, params_shape, batch=256)
    wi_spec = plan.param_specs["scan"]["b0"]["ffn"]["wi"]
    assert wi_spec[1] == "model"  # experts dim (post scan-stack offset)


def test_batch_spec_falls_back_when_indivisible():
    cfg = get_config("smollm-135m")
    mesh = _mesh()
    plan = build_plan(cfg, mesh, batch=1)
    assert plan.data_spec(2, 1) == P(None, None)  # B=1 can't shard
    plan = build_plan(cfg, mesh, batch=256)
    assert plan.data_spec(2, 256)[0] is not None
