"""Paged KV: allocator/radix-tree invariants and kernel/oracle equality.

Kernel bar: for random page tables — including pages *shared between
lanes* (radix prefix reuse) — the paged Pallas kernel (interpret mode,
real body), the paged jnp oracle, and the dense split-KV path over the
explicitly gathered cache all agree; on page-aligned logical lengths the
paged oracle is *bitwise* identical to the dense oracle, which is the
property the serving engine's stream-equality guarantees stand on.

Pool bar: pages never leak — refcounts across lanes and the radix tree
reconcile to zero when everything releases, eviction only frees
tree-exclusive pages, and lookups never hand out a prompt's final token.
"""
import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback draws (see detshim.py)
    from detshim import given, settings
    import detshim as st

from repro.core.packing import PagePool, RadixPrefixCache
from repro.kernels import ops

SENTINEL = 2 ** 30


# ---------------------------------------------------------------------------
# paged flash-decode vs oracles
# ---------------------------------------------------------------------------


def _mk_paged(rng, b, h, kvh, hd, n_pages, ps, maxp, share=True,
              dtype=jnp.float32):
    """Random arena + per-lane tables; lanes may share table entries."""
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd)), dtype) * (hd ** -0.5)
    k = jnp.asarray(rng.normal(0, 1, (n_pages, ps, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (n_pages, ps, kvh, hd)), dtype)
    # page j of any lane holds positions [j*ps, (j+1)*ps): kpos per arena
    # page is consistent for every logical depth it may appear at only if
    # tables agree on depth — build depth-consistent tables like the
    # engine's allocator does (a shared page is a shared *prefix* page)
    kpos = np.full((n_pages, ps), SENTINEL, np.int64)
    pt = np.zeros((b, maxp), np.int32)
    next_page = 1  # page 0 = trash (all sentinel)
    shared = {}
    for lane in range(b):
        for j in range(maxp):
            if share and j in shared and rng.random() < 0.5:
                pt[lane, j] = shared[j]  # prefix page shared across lanes
            else:
                page = next_page
                next_page += 1
                assert page < n_pages
                pt[lane, j] = page
                shared.setdefault(j, page)
                kpos[page] = j * ps + np.arange(ps)
    qpos = jnp.asarray(rng.integers(ps, maxp * ps, b), jnp.int32)
    return (q, k, v, jnp.asarray(kpos, jnp.int32), jnp.asarray(pt), qpos)


@given(st.integers(0, 10_000), st.sampled_from([(4, 4), (8, 2), (6, 3)]),
       st.sampled_from([(8, 3), (16, 2), (8, 5)]))
@settings(max_examples=12, deadline=None)
def test_paged_decode_interpret_matches_ref(seed, heads, paging):
    """Pallas paged kernel (interpret) == gather oracle, shared pages
    included."""
    h, kvh = heads
    ps, maxp = paging
    rng = np.random.default_rng(seed)
    b, hd = 3, 16
    n_pages = 1 + b * maxp + 1
    q, k, v, kpos, pt, qpos = _mk_paged(rng, b, h, kvh, hd, n_pages, ps,
                                        maxp)
    got = ops.paged_flash_decode(q, k, v, kpos, pt, qpos, impl="interpret")
    want = ops.paged_flash_decode(q, k, v, kpos, pt, qpos, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_paged_ref_bitwise_equals_dense_ref(seed):
    """Gathering the pages into a dense per-lane cache and running the
    dense oracle is *bitwise* what the paged oracle computes — the
    foundation of paged-vs-dense engine stream equality."""
    rng = np.random.default_rng(seed)
    b, h, kvh, hd, ps, maxp = 2, 4, 2, 16, 8, 4
    n_pages = 1 + b * maxp
    q, k, v, kpos, pt, qpos = _mk_paged(rng, b, h, kvh, hd, n_pages, ps,
                                        maxp)
    paged = ops.paged_flash_decode(q, k, v, kpos, pt, qpos, impl="ref")
    kg = jnp.asarray(np.asarray(k)[np.asarray(pt)].reshape(b, -1, kvh, hd))
    vg = jnp.asarray(np.asarray(v)[np.asarray(pt)].reshape(b, -1, kvh, hd))
    kpg = jnp.asarray(np.asarray(kpos)[np.asarray(pt)].reshape(b, -1))
    dense = ops.flash_decode(q, kg, vg, kpg, qpos, impl="ref")
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_paged_decode_inactive_and_sentinel_rows():
    """Inactive lanes and all-sentinel (never written / trash) pages give
    exact zeros, never NaN, in both impls."""
    rng = np.random.default_rng(7)
    b, h, kvh, hd, ps, maxp = 3, 4, 2, 16, 8, 3
    q, k, v, kpos, pt, qpos = _mk_paged(rng, b, h, kvh, hd, 1 + b * maxp,
                                        ps, maxp)
    pt = pt.at[2].set(0)  # lane 2's whole table points at the trash page
    active = jnp.asarray([True, False, True])
    for impl in ("ref", "interpret"):
        out = np.asarray(ops.paged_flash_decode(q, k, v, kpos, pt, qpos,
                                                active=active, impl=impl))
        assert not np.isnan(out).any(), impl
        np.testing.assert_array_equal(out[1], 0.0)  # inactive
        np.testing.assert_array_equal(out[2], 0.0)  # all-sentinel pages


def test_paged_decode_trash_page_garbage_is_unreachable():
    """Garbage k/v in the trash page (inactive lanes scatter there) must
    not perturb live lanes as long as its kpos stay sentinel."""
    rng = np.random.default_rng(11)
    b, h, kvh, hd, ps, maxp = 2, 4, 2, 16, 8, 3
    q, k, v, kpos, pt, qpos = _mk_paged(rng, b, h, kvh, hd, 1 + b * maxp,
                                        ps, maxp)
    clean = ops.paged_flash_decode(q, k, v, kpos, pt, qpos, impl="ref")
    k2 = k.at[0].set(1e9)
    v2 = v.at[0].set(-1e9)
    dirty = ops.paged_flash_decode(q, k2, v2, kpos, pt, qpos, impl="ref")
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


# ---------------------------------------------------------------------------
# PagePool / RadixPrefixCache
# ---------------------------------------------------------------------------


def test_page_pool_alloc_refcount_free():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.free_pages == 7  # page 0 reserved (trash)
    a = pool.alloc(3)
    assert pool.pages_in_use == 3 and 0 not in a
    pool.incref(a)
    assert pool.decref(a) == []          # still referenced once
    assert sorted(pool.decref(a)) == sorted(a)  # now free
    assert pool.pages_in_use == 0
    with pytest.raises(MemoryError):
        pool.alloc(8)
    assert pool.pages_for(1) == 1 and pool.pages_for(9) == 3


def test_radix_lookup_caps_at_prompt_minus_one():
    """A full-prompt hit would leave nothing to run the first forward pass
    on; the final token is never handed out."""
    pool = PagePool(num_pages=8, page_size=4)
    rc = RadixPrefixCache(pool)
    prompt = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    rc.insert(prompt, pages)
    hit, hlen = rc.lookup(prompt)  # identical prompt
    assert hlen == 4 and hit == pages[:1]
    pool.decref(hit)
    pool.decref(pages)


def test_radix_shared_prefix_hit_and_eviction():
    pool = PagePool(num_pages=12, page_size=4)
    rc = RadixPrefixCache(pool)
    prompt_a = np.concatenate([np.arange(8), [50, 51]]).astype(np.int32)
    pages_a = pool.alloc(3)
    rc.insert(prompt_a, pages_a)  # registers the 2 full pages
    assert rc.cached_pages == 2
    prompt_b = np.concatenate([np.arange(8), [60, 61, 62]]).astype(np.int32)
    hit, hlen = rc.lookup(prompt_b)
    assert hlen == 8 and hit == pages_a[:2]
    # a page held by a "lane" (the lookup ref) is not evictable
    assert rc.evict(10) == 0
    pool.decref(hit)
    pool.decref(pages_a)
    # now only tree refs remain: eviction frees exactly the cached pages
    assert rc.evict(10) == 2
    assert pool.pages_in_use == 0 and rc.cached_pages == 0


def test_radix_insert_only_full_pages():
    pool = PagePool(num_pages=8, page_size=4)
    rc = RadixPrefixCache(pool)
    prompt = np.arange(7, dtype=np.int32)  # one full page + a partial
    pages = pool.alloc(2)
    assert rc.insert(prompt, pages) == 1   # the partial page is private
    assert rc.cached_pages == 1
    pool.decref(pages)


def test_radix_lru_eviction_order():
    pool = PagePool(num_pages=8, page_size=2)
    rc = RadixPrefixCache(pool)
    old = pool.alloc(1)
    new = pool.alloc(1)
    rc.insert(np.asarray([1, 2], np.int32), old)
    rc.insert(np.asarray([3, 4], np.int32), new)
    pool.decref(old)
    pool.decref(new)
    hit, _ = rc.lookup(np.asarray([3, 4, 9], np.int32))  # refresh `new`
    pool.decref(hit)
    freed = rc.evict(1)
    assert freed == 1
    # the untouched (LRU) entry went first
    assert pool.refcount(old[0]) == 0 and pool.refcount(new[0]) == 1
