"""Synthetic data pipeline: determinism, label alignment, packing."""
import numpy as np

from repro.data.pipeline import TokenPipeline


def test_determinism():
    a = TokenPipeline(256, 4, 32, seed=7).next_batch()
    b = TokenPipeline(256, 4, 32, seed=7).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = TokenPipeline(256, 4, 32, seed=8).next_batch()
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_token():
    batch = TokenPipeline(256, 2, 16, seed=0).next_batch()
    # tokens[t+1] must equal labels[t] (same underlying document)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


def test_markov_structure_is_learnable():
    """Each token has at most `branching` successors."""
    pipe = TokenPipeline(256, 8, 64, seed=0, branching=3)
    succ = {}
    for _ in range(20):
        b = pipe.next_batch()
        for row_t, row_l in zip(b["tokens"], b["labels"]):
            for t, l in zip(row_t, row_l):
                succ.setdefault(int(t), set()).add(int(l))
    assert max(len(v) for v in succ.values()) <= 3


def test_packed_batch_invariants():
    pipe = TokenPipeline(256, 4, 64, seed=0, pack=True)
    b = pipe.next_batch()
    assert set(b) == {"tokens", "labels", "segment_ids", "positions"}
    seg, pos, lab = b["segment_ids"], b["positions"], b["labels"]
    # labels are -1 at padding and at segment ends
    assert (lab[seg < 0] == -1).all()
    # positions restart within each segment
    for r in range(seg.shape[0]):
        for c in range(1, seg.shape[1]):
            if seg[r, c] >= 0 and seg[r, c] == seg[r, c - 1]:
                assert pos[r, c] == pos[r, c - 1] + 1
    # a decent fraction of the grid is real tokens
    assert (seg >= 0).mean() > 0.5
