"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Integer kernels must be BIT-EXACT against ref.py (the paper validates its
FPGA encoder bit-for-bit against software I-BERT, §8.2); shapes and dtypes
are swept per the brief.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ibert_ops as iops
from repro.core.quant import quantize
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n", [
    (8, 128, 128), (128, 512, 256), (50, 300, 70), (1, 128, 128),
    (33, 1024, 65),
])
@pytest.mark.parametrize("requant", [False, True])
def test_int8_matmul_shapes(m, k, n, requant):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    sa, sb, so = jnp.float32(0.013), jnp.float32(0.021), jnp.float32(0.4)
    bias = jnp.asarray(rng.integers(-500, 500, (n,)), jnp.int32)
    got = ops.int8_matmul(a, b, sa, sb, s_out=so if requant else None,
                          bias=bias, impl="interpret")
    want = ref.int8_matmul(a, b, sa, sb, bias=bias,
                           s_out=so if requant else None)
    assert got.dtype == (jnp.int8 if requant else jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rows,cols", [(8, 64), (13, 77), (64, 128), (1, 9)])
def test_i_softmax_kernel(rows, cols):
    rng = np.random.default_rng(rows * 100 + cols)
    x = rng.normal(0, 4, (rows, cols)).astype(np.float32)
    q = quantize(x, bits=iops.ACT_BITS)
    qv = q.values.astype(jnp.int32)
    got = ops.i_softmax(qv, q.scale, impl="interpret")
    want = ref.i_softmax_rows(qv, q.scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the result is a valid distribution at scale 2^-14
    p = np.asarray(got) * 2.0 ** -iops.SOFTMAX_OUT_BITS
    assert np.all(p >= 0)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=2e-2)


@pytest.mark.parametrize("rows,h", [(8, 768), (9, 576), (16, 64), (3, 8192)])
def test_i_layernorm_kernel(rows, h):
    rng = np.random.default_rng(rows + h)
    x = rng.normal(0, 2, (rows, h)).astype(np.float32)
    q = quantize(x, bits=8)
    qv = q.values.astype(jnp.int32)
    prep = iops.layernorm_prepare(
        jnp.asarray(rng.uniform(0.5, 1.5, h).astype(np.float32)),
        jnp.asarray(rng.normal(0, 0.1, h).astype(np.float32)))
    got, s_got = ops.i_layernorm(qv, prep, impl="interpret")
    want = ref.i_layernorm_rows(qv, prep.q_gamma, prep.q_beta, prep.s_gamma)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(37, 100), (64, 64), (1, 5), (128, 300)])
def test_i_gelu_kernel(shape):
    rng = np.random.default_rng(shape[0])
    x = rng.uniform(-6, 6, shape).astype(np.float32)
    q = quantize(x, bits=iops.ACT_BITS)
    qv = q.values.astype(jnp.int32)
    got = ops.i_gelu(qv, q.scale, impl="interpret")
    want = ref.i_gelu_elem(qv, q.scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_matmul_kernel_direct_tiling():
    """Direct pallas_call on exactly-tiled shapes (no ops padding)."""
    from repro.kernels.int8_matmul import int8_matmul as raw
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (256, 1024)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (1024, 256)), jnp.int8)
    got = raw(a, b, jnp.float32(0.01), jnp.float32(0.02), interpret=True)
    want = ref.int8_matmul(a, b, jnp.float32(0.01), jnp.float32(0.02))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_flash_attention_ref_matches_dense():
    """The chunked online-softmax path == dense attention oracle."""
    from repro.models import attention as am
    rng = np.random.default_rng(1)
    b, s, h, hd = 2, 64, 3, 16
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    msk = am._mask(s, s, pos, pos, True, 0)
    dense = am._dense_attention(q, k, v, msk)
    chunked = am._chunked_attention(q, k, v, pos, pos, True, 0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)
