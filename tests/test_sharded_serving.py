"""Sharded serving: the scheduler/executor/kv-manager split and the
plan-sharded decode paths.

Multi-device cases run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax init — same pattern as tests/test_pipeline.py) and assert
the tentpole contracts: a ``mode="serve"`` plan shards the paged arena's
kv-head dim across the mesh with BIT-IDENTICAL token streams (bf16 and
int8, cold and prefix-hit), and a ``mode="serve_pipeline"`` plan streams
decode through the stage axis bit-identically to ``Model.decode_steps``.
Host-side layer tests (no devices) cover the split's independence.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np


def _run(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# -- the three layers stand alone ------------------------------------------


def test_scheduler_and_kv_manager_import_without_jax():
    """Acceptance: the host-side layers are importable (and constructible)
    independently — no jax in the process."""
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import sys
        sys.path.insert(0, %r)
        from repro.serving.scheduler import Request, Scheduler
        from repro.serving.kv_manager import KVManager, kv_page_bytes
        s = Scheduler((16, 32), 0.05, 8, 4)
        kv = KVManager(num_pages=9, page_size=4, max_batch=2, max_pages=4)
        assert "jax" not in sys.modules, "host layers must not pull jax"
        print("NOJAX-OK")
    """ % os.path.join(os.path.dirname(__file__), "..", "src"))],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "NOJAX-OK" in out.stdout


def test_scheduler_horizon_ladder_standalone():
    from repro.serving.scheduler import Scheduler

    s = Scheduler((16, 32), 0.05, decode_horizon=8, max_batch=4)
    assert s.horizons == [1, 2, 4, 8]
    # waiting: floor 4, aim at min remaining
    assert s.pick_horizon(True, [7, 12]) == 4
    assert s.pick_horizon(True, [1]) == 4  # floored
    assert s.pick_horizon(False, [3, 9]) == 8  # drained: run long
    assert Scheduler((16,), 0.0, 1, 1).pick_horizon(False, [64]) == 1


def test_kv_manager_grant_and_release_standalone():
    from repro.serving.kv_manager import KVManager

    kv = KVManager(num_pages=9, page_size=4, max_batch=2, max_pages=6)
    prompt = np.arange(9, dtype=np.int32)
    g = kv.admit(prompt, rem_budget=4, max_hit_suffix=16)  # 13 pos -> 4 pg
    assert g is not None and len(g.pages) == 4 and g.hit_len == 0
    assert g.pt_row[:4].tolist() == g.pages and g.pt_row[4:].tolist() == [0, 0]
    kv.commit(0, g)
    kv.register_prefix(prompt, g.pages)  # 2 full pages registered
    assert kv.prefix_cache.cached_pages == 2
    # second identical prompt hits the 2-page prefix (8 of 9 tokens)
    g2 = kv.admit(prompt, rem_budget=4, max_hit_suffix=16)
    assert g2 is not None and g2.hit_len == 8
    assert g2.pages[:2] == g.pages[:2]  # shared, copy-free
    kv.commit(1, g2)
    kv.release(0)
    kv.release(1)
    kv.assert_drained()  # only tree references remain


def test_paged_arena_specs_kv_head_sharded():
    """Cluster-Builder paged leaf rules: arena k/v + scale planes shard
    the kv-head dim over `model`; kpos/pt/pos replicate (spec-only,
    abstract mesh — no devices needed)."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_abstract_mesh
    from repro.models.transformer import make_model

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_heads=8, n_kv_heads=8)
    model = make_model(cfg, remat=False)
    mesh = make_abstract_mesh((1, 8), ("data", "model"))
    plan = build_plan(cfg, mesh, mode="serve")
    shape = jax.eval_shape(
        lambda: model.init_paged_cache(4, 32, 8, 8, kv_dtype="int8"))
    specs = plan.specs_for_caches(shape, batch=4, paged=True)
    b0 = specs["scan"]["b0"]
    assert b0["k"][3] == "model" and b0["v"][3] == "model"
    assert b0["k_scale"][3] == "model" and b0["v_scale"][3] == "model"
    assert all(p is None for p in b0["kpos"])
    assert all(p is None for p in specs["pt"])
    assert all(p is None for p in specs["pos"])
    # indivisible kv heads fall back to replication, never uneven shards
    cfg3 = dataclasses.replace(cfg, n_heads=9, n_kv_heads=3)
    model3 = make_model(cfg3, remat=False)
    shape3 = jax.eval_shape(
        lambda: model3.init_paged_cache(4, 32, 8, 8))
    specs3 = build_plan(cfg3, mesh, mode="serve").specs_for_caches(
        shape3, batch=4, paged=True)
    assert all(p is None for p in specs3["scan"]["b0"]["k"])


# -- tentpole: sharded-vs-unsharded bit identity (8 host devices) ----------


def test_sharded_serve_bit_identical_bf16_int8_and_prefix_hits():
    """serve-mode plan on a (1, 8) mesh: the paged engine's token streams
    — bf16 and int8, cold and via radix prefix hits on the sharded arena —
    are bit-identical to the single-device paged engine's."""
    _run("""
    import dataclasses
    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine, Request

    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_heads=8, n_kv_heads=8)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, cfg.vocab_size, 35).astype(np.int32)

    def reqs():
        out = []
        for i in range(5):
            tail = np.random.default_rng(100 + i).integers(
                0, cfg.vocab_size, 4).astype(np.int32)
            out.append(Request(rid=i,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new_tokens=4 + i % 3))
        return out

    def streams(eng):
        for r in reqs():
            eng.submit(r)
        return {r.rid: tuple(r.tokens_out) for r in eng.run()}

    mesh = make_mesh((1, 8), ("data", "model"))
    plan = build_plan(cfg, mesh, mode="serve")
    with kops.pinned_impl("ref"):
        for kv_dtype in ("bf16", "int8"):
            single = ContinuousBatchingEngine(
                model, params, max_batch=2, buckets=(48,),
                max_decode_len=16, kv_dtype=kv_dtype)
            shard = ContinuousBatchingEngine(
                model, params, max_batch=2, buckets=(48,),
                max_decode_len=16, kv_dtype=kv_dtype, plan=plan)
            assert shard.paged and shard.plan is plan
            # pass 1: cold prefills on both
            assert streams(single) == streams(shard), kv_dtype
            # pass 2: every admission after the first is a radix hit ON
            # THE SHARDED ARENA; streams must still match bit-for-bit
            s1, s2 = streams(single), streams(shard)
            assert s1 == s2, (kv_dtype, s1, s2)
            assert shard.stats["prefix_hits"] >= 4, shard.stats
            # the arena is REALLY distributed: kv-head dim on `model`
            k = shard._slot_caches["scan"]["b0"]["k"]
            assert k.sharding.spec[3] == "model", k.sharding.spec
            if kv_dtype == "int8":
                ks = shard._slot_caches["scan"]["b0"]["k_scale"]
                assert ks.sharding.spec[3] == "model", ks.sharding.spec
            print(f"SHARDED-{kv_dtype}-OK")
    """)


def test_serve_pipeline_matches_decode_steps():
    """serve_pipeline plan on a 4-stage mesh: the executor's
    collective_permute-streamed decode program and the engine built on it
    emit exactly what single-device `Model.decode_steps` emits."""
    _run("""
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_mesh
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine, Request
    from repro.serving.executor import Executor

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              n_layers=4)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((4,), ("stage",))
    plan = build_plan(cfg, mesh, mode="serve_pipeline")
    rng = np.random.default_rng(0)

    with kops.pinned_impl("ref"):
        # executor-level: pipelined fused loop == Model.decode_steps
        B, L = 8, 24
        ex = Executor(model, params, plan=plan, max_batch=B, cache_len=L,
                      buckets=(16,))
        st = ex.fresh_state(ex.init_caches(False), paged=False)
        tok0 = np.zeros(B, np.int32)
        for sl in range(B):
            p = rng.integers(0, cfg.vocab_size, 5 + sl).astype(np.int32)
            logits, small = ex.prefill_prompts([p], 1, bucket_cache=True)
            st["caches"] = ex.insert(st["caches"], small, sl)
            tok0[sl] = int(jnp.argmax(logits[0]))
            ex.admit_lane(st, sl, int(tok0[sl]), -1, 5 + sl % 3)
        ref_caches = jax.tree.map(jnp.asarray,
                                  jax.device_get(st["caches"]))
        toks_ref, *_ = model.decode_steps(
            params, ref_caches, jnp.asarray(tok0), st["active"], 8,
            eos_id=st["eos"], budget=st["budget"], pad_token=0)
        toks_pipe = ex.decode(st, 8, paged=False)
        assert np.array_equal(np.asarray(toks_ref), np.asarray(toks_pipe))
        print("PIPE-EXEC-OK")

        # engine-level: serve_pipeline streams == plan-free dense streams
        prompts = [rng.integers(0, cfg.vocab_size, k).astype(np.int32)
                   for k in (5, 9, 12, 6, 8)]
        budgets = [3, 8, 5, 6, 4]

        def run(plan_):
            eng = ContinuousBatchingEngine(model, params, max_batch=4,
                                           buckets=(16,), plan=plan_,
                                           paged=False)
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=i, prompt=p,
                                   max_new_tokens=budgets[i]))
            return {r.rid: r.tokens_out for r in eng.run()}

        assert run(None) == run(plan)
        print("PIPE-ENGINE-OK")
    """)


def test_serve_dryrun_prints_shardings():
    """launch/serve.py --dryrun: per-leaf shardings are printed (and
    nothing is served) for both plan modes."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--plan", "serve", "--mesh", "1,8",
         "--dryrun"], capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "mode=serve" in out.stdout and "paged arena" in out.stdout
    assert "scan/b0/mix/wq" in out.stdout and "'model'" in out.stdout
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-135m", "--reduced", "--plan", "serve_pipeline", "--mesh",
         "2", "--dryrun"], capture_output=True, text=True, env=env,
        timeout=300)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "mode=serve_pipeline" in out.stdout
    assert "'stage'" in out.stdout
