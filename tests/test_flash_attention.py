"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode).

The §Perf-C structural lever: VMEM-resident online-softmax carries.  Swept
over shapes/dtypes/causal per the brief; tolerance follows bf16 matmul
precision.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _mk(b, s, h, kvh, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kvh, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("s", [64, 256, 300, 512])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle_shapes(s, causal):
    q, k, v = _mk(2, s, 4, 4, 32, jnp.float32, seed=s)
    got = ops.flash_attention(q, k, v, causal=causal, impl="interpret")
    want = ops.flash_attention(q, k, v, causal=causal, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 1)])
def test_flash_gqa(h, kvh):
    q, k, v = _mk(2, 128, h, kvh, 16, jnp.float32, seed=h)
    got = ops.flash_attention(q, k, v, causal=True, impl="interpret")
    want = ops.flash_attention(q, k, v, causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(1, 256, 2, 2, 64, jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, impl="interpret")
    want = ops.flash_attention(q, k, v, causal=True, impl="ref")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_long_prefill_routes_through_fused_kernel(monkeypatch):
    """attention() routes contiguous long prefill through ops.flash_attention
    (impl-gated) and the result tracks the chunked jax formulation; padded
    positions or segment ids must keep the ref/chunked fallback."""
    from repro.models import attention as am
    from repro.models.attention import attn_init, attention
    from repro.configs import get_config

    cfg = get_config("smollm-135m").reduced()
    p = attn_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 128
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (b, s, cfg.d_model)),
                    jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    monkeypatch.setattr(am, "DENSE_ATTN_MAX_KV", 32)  # force the long path
    calls = []
    real = ops.flash_attention

    def spy(*a, **kw):
        calls.append(kw.get("impl"))
        return real(*a, **kw)

    monkeypatch.setattr(ops, "flash_attention", spy)
    prev = ops._IMPL
    try:
        ops.set_impl("interpret")
        fused, _ = attention(x, p, cfg, pos, pos_contiguous=True)
        assert calls, "fused prefill kernel was not routed to"
        # non-contiguous positions (pad sentinels) must not take the kernel
        n = len(calls)
        attention(x, p, cfg, pos, pos_contiguous=False)
        assert len(calls) == n
        # interpret mode replays the grid at trace time: an over-budget
        # grid (b*h * ceil(S/256)^2 > INTERPRET_MAX_GRID) must fall back
        big_s = 2048  # 16*4 heads-batch * 8^2 splits = 4096 programs
        xb = jnp.zeros((16, big_s, cfg.d_model), jnp.bfloat16)
        pb = jnp.broadcast_to(jnp.arange(big_s, dtype=jnp.int32),
                              (16, big_s))
        attention(xb, p, cfg, pb, pos_contiguous=True)
        assert len(calls) == n
        ops.set_impl("ref")
        chunked, _ = attention(x, p, cfg, pos, pos_contiguous=True)
    finally:
        ops._IMPL = prev
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(chunked, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_matches_model_attention_path():
    """Cross-check against the model's chunked online-softmax (the jax
    formulation the dry-run lowers) — all three agree."""
    from repro.models import attention as am

    q, k, v = _mk(2, 256, 4, 4, 16, jnp.float32, seed=7)
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    # the model path receives q already scaled by 1/sqrt(hd)
    chunked = am._chunked_attention(q / 4.0, k, v, pos, pos, True, 0)
    flash = ops.flash_attention(q, k, v, causal=True, impl="interpret")
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(flash),
                               atol=2e-5, rtol=2e-5)
