"""Paper Eq. 1 latency model + derived quantities (§8)."""
import numpy as np

from repro.core.latency_model import (
    StageTiming, estimate_table2, fit_x_fraction, pipeline_bubble_fraction,
    throughput, total_latency,
)


def test_eq1_reproduces_paper_table2_at_128():
    """Paper: seq 128 -> X=111708cy, T=209789cy @~200MHz clock-equivalent;
    with d=1.1us and L=12 the paper reports 7.193 ms.  We verify the
    formula against the paper's own cycle numbers (5ns/cycle)."""
    cyc = 5e-9  # the numbers in Table 1/2 are consistent with a 200MHz clock
    t = StageTiming(T=209789 * cyc, X=111708 * cyc, d=1.1e-6)
    total = total_latency(t, 12)
    assert abs(total - 7.193e-3) / 7.193e-3 < 0.02


def test_eq1_seq1_matches_paper():
    cyc = 5e-9
    t = StageTiming(T=6936 * cyc, X=6936 * cyc, d=1.1e-6)
    assert abs(total_latency(t, 12) - 0.416e-3) / 0.416e-3 < 0.03


def test_throughput_is_slowest_stage_rate():
    t = StageTiming(T=494e-6, X=260e-6, d=1.1e-6)
    # paper §8.2.3: ~2023 inferences/s at seq 128 (T = 1/2023 s)
    assert abs(throughput(StageTiming(T=1 / 2023.47, X=0, d=0)) - 2023.47) \
        < 0.1
    assert throughput(t) == 1 / 494e-6


def test_x_fraction_fit():
    # §9: X ~= 0.53 T at seq 128
    ts = [209789.0]
    xs = [111708.0]
    f = fit_x_fraction(xs, ts)
    assert abs(f - 0.5325) < 0.01


def test_bubble_fraction():
    assert pipeline_bubble_fraction(1, 12) == 11 / 12
    assert pipeline_bubble_fraction(44, 12) == 11 / 55
    assert pipeline_bubble_fraction(100, 1) == 0.0


def test_estimate_table2_structure():
    t_by_seq = {1: 6936 * 5e-9, 128: 209789 * 5e-9}
    x_by_seq = {1: 6936 * 5e-9, 128: 111708 * 5e-9}
    out = estimate_table2(t_by_seq, x_by_seq, d=1.1e-6, n_stages=12)
    assert out[128] > out[1]
    assert set(out) == {1, 128}
