"""Checkpoint manager: roundtrip, atomicity, integrity, pruning."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(0, 1, (8, 16)).astype(np.float32)),
            "emb": jnp.asarray(rng.integers(-5, 5, (4, 4)), jnp.int8),
        },
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_bit_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save(3, tree, blocking=True)
    step, restored = mgr.restore()
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))  # async
    mgr.save(2, _tree(2))  # waits for 1, then async
    mgr.wait()
    assert mgr.all_steps() == [1, 2]
    _, restored = mgr.restore(1)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_tree(1)["params"]["w"]))


def test_prune_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checksum_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, _tree(), blocking=True)
    d = os.path.join(str(tmp_path), "step_00000005")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    victim = next(iter(manifest["leaves"].values()))["file"]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        mgr.restore(5)


def test_tmp_dirs_are_not_valid_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.all_steps() == []
    with pytest.raises(FileNotFoundError):
        mgr.restore()
