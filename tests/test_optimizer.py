"""Optimizers: AdamW, int8-state AdamW, schedules, compression residuals."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.compression import (
    block_dequantize, block_quantize, quantize_residual,
)
from repro.optim.optimizer import (
    adamw, adamw8, clip_by_global_norm, cosine_schedule, global_norm,
    make_optimizer, sgdm,
)


def _quad_problem(opt_name, steps=60, lr=0.05):
    """Minimize ||x - t||^2; returns final distance."""
    t = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
    params = {"x": jnp.zeros((32,), jnp.float32)}
    init, update = make_optimizer(opt_name, lambda s: jnp.float32(lr))
    state = init(params)

    def loss(p):
        return jnp.sum((p["x"] - t) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params, wd=0.0)
    return float(jnp.max(jnp.abs(params["x"] - t)))


@pytest.mark.parametrize("opt", ["adamw", "adamw8", "sgdm"])
def test_optimizers_converge(opt):
    assert _quad_problem(opt) < 0.05


def test_adamw8_tracks_adamw():
    """int8 moment quantization must not change the trajectory materially."""
    d1 = _quad_problem("adamw", steps=40)
    d8 = _quad_problem("adamw8", steps=40)
    assert abs(d1 - d8) < 0.1


def test_adamw8_state_is_int8():
    params = {"w": jnp.zeros((300,), jnp.float32)}
    init, update = make_optimizer("adamw8", lambda s: jnp.float32(1e-3))
    state = init(params)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    g = {"w": jnp.ones((300,), jnp.float32)}
    params2, state2 = update(g, state, params)
    assert state2["m"]["w"]["q"].dtype == jnp.int8
    assert float(jnp.max(jnp.abs(params2["w"]))) > 0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.11
    assert float(lr(jnp.asarray(100))) < 1e-6
    assert float(lr(jnp.asarray(55))) < float(lr(jnp.asarray(20)))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_block_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (1000,)).astype(np.float32))
    q, s, pad = block_quantize(x)
    back = block_dequantize(q, s, pad, x.shape)
    # per-block error <= block_scale/2
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= float(jnp.max(s)) * 0.5 + 1e-7


def test_error_feedback_residual():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (512,)).astype(np.float32))
    (_, _, _), resid = quantize_residual(x)
    # residual is exactly the quantization error
    assert float(jnp.max(jnp.abs(resid))) <= float(
        jnp.max(jnp.abs(x))) / 127 + 1e-6
