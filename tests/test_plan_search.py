"""Cost-model-driven serve-plan auto-search (docs/serving.md §plan
auto-search): determinism, HBM feasibility pruning, Pareto invariants,
grid coverage, and the cost model's agreement with the latency_model
Table 1/2 fixtures."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.latency_model import (StageTiming, decode_step_latency,
                                      pipeline_ticks_per_step,
                                      total_latency)
from repro.core.plan_search import (Candidate, DeviceCalibration,
                                    HardwareModel, PlanSearchError,
                                    TrafficProfile, X_FRACTION, choose,
                                    diff_snapshots, engine_kwargs,
                                    enumerate_candidates, pareto_frontier,
                                    predict_engine_tok_s, realize, search,
                                    to_snapshot)

SMALL = get_config("smollm-135m")
PROFILE = TrafficProfile()


@pytest.fixture(scope="module")
def result():
    return search(SMALL, PROFILE)


# ---------------------------------------------------------------------------
# determinism + snapshots
# ---------------------------------------------------------------------------


def test_search_deterministic(result):
    again = search(SMALL, PROFILE)
    assert to_snapshot(SMALL, result) == to_snapshot(SMALL, again)
    assert [s.key for s in result.frontier] == [s.key for s in again.frontier]
    assert result.chosen.key == again.chosen.key


def test_snapshot_diff_clean_and_drifted(result):
    snap = to_snapshot(SMALL, result)
    hard, info = diff_snapshots(snap, snap)
    assert hard == [] and info == []
    drifted = dict(snap, chosen=dict(snap["chosen"], key="serve.tp8.other"))
    hard, _ = diff_snapshots(snap, drifted)
    assert any("chosen.key" in line for line in hard)
    # predicted-number movement alone is informational, not hard drift
    wobble = dict(snap, chosen=dict(
        snap["chosen"],
        predicted={k: v * 1.5 for k, v in snap["chosen"]["predicted"].items()}))
    hard, info = diff_snapshots(snap, wobble)
    assert hard == [] and info


def test_profile_rejects_unknown_keys(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"arrival_rate": 2.0, "hbm_gigs": 99}')
    with pytest.raises(PlanSearchError, match="hbm_gigs"):
        TrafficProfile.from_json(str(p))


# ---------------------------------------------------------------------------
# feasibility pruning
# ---------------------------------------------------------------------------


def test_400b_on_small_budget_never_selects_oom_plan():
    """A 400B-class config on the default 8x16GB budget cannot fit even
    int8 weights on any enumerated candidate — the search must prune
    every candidate rather than pick one that would OOM."""
    big = get_config("llama4-maverick-400b-a17b")
    res = search(big, PROFILE)
    assert res.chosen is None
    assert res.n_feasible == 0
    assert res.frontier == []
    assert all("weights" in s.reason or "KV" in s.reason
               for s in res.scores)


def test_feasible_candidates_fit_the_budget(result):
    for s in result.scores:
        if s.feasible:
            assert 0.0 < s.hbm_frac <= 1.0, s.key
            assert s.lanes >= 1
            assert s.cand.width * s.replicas <= PROFILE.devices


def test_bigger_budget_is_monotone():
    """Growing the HBM budget can only keep or grow the feasible set."""
    big = get_config("phi3-medium-14b")
    lo = search(big, dataclasses.replace(PROFILE, hbm_gb=8.0))
    hi = search(big, dataclasses.replace(PROFILE, hbm_gb=32.0))
    feas_lo = {s.key for s in lo.scores if s.feasible}
    feas_hi = {s.key for s in hi.scores if s.feasible}
    assert feas_lo <= feas_hi


# ---------------------------------------------------------------------------
# pareto invariants
# ---------------------------------------------------------------------------


def _dominates(a, b):
    ge = (a.tok_s >= b.tok_s and a.ttft_ms <= b.ttft_ms
          and a.hbm_frac <= b.hbm_frac)
    gt = (a.tok_s > b.tok_s or a.ttft_ms < b.ttft_ms
          or a.hbm_frac < b.hbm_frac)
    return ge and gt


def test_frontier_is_nondominated_and_covers_chosen(result):
    front = result.frontier
    assert front, "default profile must admit at least one candidate"
    for s in front:
        assert s.feasible
        assert not any(_dominates(o, s) for o in front if o is not s)
    # every feasible non-frontier point is dominated by some frontier point
    feas = [s for s in result.scores if s.feasible]
    fkeys = {s.key for s in front}
    for s in feas:
        if s.key not in fkeys:
            assert any(_dominates(f, s) for f in front), s.key
    # the chosen plan is itself Pareto-optimal
    assert result.chosen.key in fkeys


def test_choose_respects_ttft_target(result):
    tight = min(s.ttft_ms for s in result.scores if s.feasible) * 1.01
    prof = dataclasses.replace(PROFILE, ttft_target_ms=tight)
    ch = choose(result.scores, prof)
    assert ch.ttft_ms <= tight
    # unconstrained choice is the global tok/s argmax
    best = max(s.tok_s for s in result.scores if s.feasible)
    assert choose(result.scores, PROFILE).tok_s == best


def test_pareto_frontier_empty_when_nothing_feasible():
    assert pareto_frontier([]) == []


# ---------------------------------------------------------------------------
# grid coverage + realization
# ---------------------------------------------------------------------------


def test_exact_and_throughput_both_enumerated():
    cands = enumerate_candidates(SMALL, PROFILE)
    serve = [c for c in cands if c.mode == "serve"]
    pipe = [c for c in cands if c.mode == "serve_pipeline"]
    assert {c.exact for c in serve if c.tp > 1} == {True, False}
    assert {c.exact for c in pipe} == {True, False}
    # the declared grid axes all vary
    assert {c.page_size for c in cands} >= {8, 16, 32}
    assert {c.kv_dtype for c in cands} == {"bf16", "int8"}
    assert {c.quant_weights for c in cands} == {True, False}
    assert len({c.tp for c in serve}) > 1
    # exact pipelines stream dense slots (engine asserts paged off)
    assert all(c.page_size == 0 and c.kv_dtype == "bf16"
               for c in pipe if c.exact)
    # int8 KV only rides the paged pool
    assert all(c.page_size > 0 for c in cands if c.kv_dtype == "int8")
    assert len(cands) == len(set(cands))


def test_stage_depths_divide_the_layer_stack():
    cands = enumerate_candidates(SMALL, PROFILE)  # 30-layer stack
    depths = {c.stages for c in cands if c.mode == "serve_pipeline"}
    assert depths == {2}  # of divisors(8), only 2 divides 30
    cfg48 = get_config("moonshot-v1-16b-a3b")  # 48 layers
    depths48 = {c.stages for c in enumerate_candidates(cfg48, PROFILE)
                if c.mode == "serve_pipeline"}
    assert depths48 == {2, 4, 8}


def test_realize_and_engine_kwargs(result):
    plan = realize(SMALL, result.chosen)
    cand = result.chosen.cand
    assert plan.mode == cand.mode
    assert plan.exact == cand.exact
    kw = engine_kwargs(result.chosen)
    assert kw["paged"] == cand.paged
    if cand.paged:
        assert kw["page_size"] == cand.page_size
        assert kw["kv_dtype"] == cand.kv_dtype


# ---------------------------------------------------------------------------
# cost model vs the paper fixtures (Table 1/2)
# ---------------------------------------------------------------------------


def test_pipeline_fill_matches_table2_fixture():
    """The search prices pipeline TTFT with the same Eq. 1 the paper's
    Table 2 validates: T=209789cy, X=111708cy @5ns, d=1.1us, L=12
    -> 7.193 ms within 2%."""
    cyc = 5e-9
    t = StageTiming(T=209789 * cyc, X=111708 * cyc, d=1.1e-6)
    assert abs(total_latency(t, 12) - 7.193e-3) / 7.193e-3 < 0.02
    # the X~=0.53T §9 fit the search substitutes when only T is known
    fitted = StageTiming(T=t.T, X=X_FRACTION * t.T, d=t.d)
    assert abs(total_latency(fitted, 12)
               - total_latency(t, 12)) / total_latency(t, 12) < 0.02


def test_pipeline_ticks_per_step_schedules():
    assert pipeline_ticks_per_step(1, exact=True) == 1
    assert pipeline_ticks_per_step(6, exact=True) == 11   # drained 2S-1
    assert pipeline_ticks_per_step(6, exact=False) == 6   # skewed S
    t_stage, d = 209789 * 5e-9, 1.1e-6
    drained = decode_step_latency(t_stage, 6, d, exact=True)
    skewed = decode_step_latency(t_stage, 6, d, exact=False)
    assert drained == pytest.approx(11 * (t_stage + d))
    assert skewed == pytest.approx(6 * (t_stage + d))
    assert skewed < drained


def test_hardware_model_hop_is_the_papers_d():
    hw = HardwareModel()
    assert hw.hop_s == pytest.approx(1.1e-6)
    assert hw.peak(True) == 2 * hw.peak(False)  # int8 doubles the MXU


def test_search_prices_exact_pipeline_above_skewed():
    """Same knobs, drained vs skewed schedule: the 2S-1 tick exact
    pipeline can never out-throughput the S-tick skewed one under the
    same profile (the cost-model analogue of serve_throughput's gate)."""
    cfg = get_config("moonshot-v1-16b-a3b")
    res = search(cfg, PROFILE)
    by_key = {s.key: s for s in res.scores}
    for s in res.scores:
        c = s.cand
        if (c.mode == "serve_pipeline" and not c.exact and s.feasible
                and c.kv_dtype == "bf16" and not c.quant_weights):
            twin = dataclasses.replace(c, exact=True, page_size=0)
            ex = by_key.get(twin.key)
            if ex is not None and ex.feasible:
                assert s.tok_s >= ex.tok_s, (s.key, ex.key)


# ---------------------------------------------------------------------------
# calibration + prediction plumbing
# ---------------------------------------------------------------------------


def test_two_point_calibration_recovers_costs():
    step, disp = 2e-3, 5e-3
    cal = DeviceCalibration.from_two_point(disp + 1 * step, 1,
                                           disp + 8 * step, 8)
    assert cal.t_step_s == pytest.approx(step)
    assert cal.t_dispatch_s == pytest.approx(disp)


def test_predict_engine_tok_s_scales_sanely():
    cal = DeviceCalibration(t_step_s=2e-3, t_dispatch_s=0.0,
                            t_prefill_s=3e-3)
    kw = dict(n_requests=16, total_tokens=800, prompt_tokens=640,
              max_batch=4, horizon=8)
    base = predict_engine_tok_s(cal, **kw)
    faster = predict_engine_tok_s(
        DeviceCalibration(1e-3, 0.0, 3e-3), **kw)
    assert faster > base > 0
    # dispatch overhead can only slow the prediction down
    lossy = predict_engine_tok_s(
        DeviceCalibration(2e-3, 5e-3, 3e-3), **kw)
    assert lossy < base
