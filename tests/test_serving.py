"""Serving engine: batched greedy decode must equal unbatched forward."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import init_params, make_model
from repro.serving.engine import Request, ServingEngine


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "xlstm-1.3b"])
def test_batched_serving_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=3, buckets=(16, 32))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 12)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3 and all(r.done for r in done)

    for r, p in zip(done, prompts):
        seq = list(p)
        exp = []
        for _ in range(4):
            logits = model.forward_logits(params, tokens=jnp.asarray([seq]))
            t = int(jnp.argmax(logits[0, -1]))
            exp.append(t)
            seq.append(t)
        assert exp == r.tokens_out, (r.rid, exp, r.tokens_out)


def test_engine_multiple_waves_and_stats():
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, buckets=(16,))
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert eng.stats["waves"] == 3  # 2 + 2 + 1
    assert all(len(r.tokens_out) == 3 for r in done)
    assert all(r.t_first_token >= r.t_enqueue for r in done)


def test_eos_stops_request():
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # find what the first generated token will be, then use it as EOS
    logits = model.forward_logits(params, tokens=jnp.asarray([prompt]))
    first = int(jnp.argmax(logits[0, -1]))
    eng = ServingEngine(model, params, max_batch=1, buckets=(16,))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=first))
    done = eng.run()
    assert done[0].tokens_out[0] == first
    assert len(done[0].tokens_out) <= 2
