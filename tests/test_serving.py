"""Serving engines: wave baseline + slot-based continuous batching.

Correctness bar: batched greedy decode equals the unbatched forward, the
continuous engine's token streams are identical to the wave engine's, and
the Cluster-Builder serve plan's shardings are actually applied to the
engine's params and persistent slot cache.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.core.packing import AdmissionPolicy
from repro.models.transformer import init_params, make_model
from repro.runtime.stragglers import AdmissionDeadline
from repro.serving.engine import (
    ContinuousBatchingEngine, Request, ServingEngine, WaveEngine,
)


def _setup(arch="smollm-135m"):
    cfg = get_config(arch).reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture
def ref_impl():
    """Pin the kernel impl to the jnp oracle for cross-path comparisons.

    Greedy token streams are only comparable when prefill and decode share
    one impl: the reduced configs' bf16 logits carry exact top-2 ties, and
    any summation reorder (dense softmax vs the kernel's online softmax)
    breaks them differently.  Batched-vs-unbatched equality under the
    default (Pallas) impl is covered by test_fused_horizon_* below and
    tests/test_flash_decode.py.
    """
    from repro.kernels import ops
    prev = ops._IMPL
    ops.set_impl("ref")
    yield
    ops._IMPL = prev


def test_serving_engine_is_continuous():
    assert ServingEngine is ContinuousBatchingEngine


@pytest.mark.parametrize("engine_cls", [WaveEngine, ContinuousBatchingEngine])
@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "xlstm-1.3b"])
def test_batched_serving_matches_forward(arch, engine_cls, ref_impl):
    cfg, model, params = _setup(arch)
    eng = engine_cls(model, params, max_batch=3, buckets=(16, 32))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 12)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert len(done) == 3 and all(r.done for r in done)

    for r, p in zip(done, prompts):
        seq = list(p)
        exp = []
        for _ in range(4):
            logits = model.forward_logits(params, tokens=jnp.asarray([seq]))
            t = int(jnp.argmax(logits[0, -1]))
            exp.append(t)
            seq.append(t)
        assert exp == r.tokens_out, (r.rid, exp, r.tokens_out)


def test_continuous_matches_wave_token_streams(ref_impl):
    """Same request set, mixed budgets spanning several admission cycles:
    the slot engine's outputs must be identical to the wave engine's.
    (ref-pinned: the continuous engine runs the paged arena while the wave
    baseline decodes dense slots — cross-layout equality is exact only
    under one attention formulation, docs/perf.md §impl selection.)"""
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 14, 9, 3, 11, 7, 12, 6)]
    budgets = [3, 8, 1, 6, 2, 7, 4, 5]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=budgets[i])
                for i, p in enumerate(prompts)]

    wave = WaveEngine(model, params, max_batch=3, buckets=(16, 32))
    for r in reqs():
        wave.submit(r)
    out_w = {r.rid: r.tokens_out for r in wave.run()}

    cb = ContinuousBatchingEngine(model, params, max_batch=3,
                                  buckets=(16, 32))
    for r in reqs():
        cb.submit(r)
    done = cb.run()
    out_c = {r.rid: r.tokens_out for r in done}
    assert out_w == out_c
    assert all(len(out_c[i]) == budgets[i] for i in range(len(budgets)))
    # slot engine never idles a full table: fewer or equal decode steps
    assert cb.stats["decode_steps"] <= wave.stats["decode_steps"]
    assert cb.stats["admitted"] == cb.stats["completed"] == len(prompts)


@pytest.mark.parametrize("engine_cls", [WaveEngine, ContinuousBatchingEngine])
def test_fused_horizon_streams_match_single_step(engine_cls):
    """Acceptance: under the default impl, the fused decode fast path
    (horizon n, one dispatch per n tokens) emits token streams bit-identical
    to the one-dispatch-per-token engine, with >= 4x fewer dispatches."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 14, 9, 3, 11, 7)]
    budgets = [9, 3, 12, 6, 16, 8]

    def run(horizon):
        eng = engine_cls(model, params, max_batch=3, buckets=(16, 32),
                         decode_horizon=horizon)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=budgets[i]))
        return {r.rid: r.tokens_out for r in eng.run()}, eng.stats

    out1, stats1 = run(1)
    out8, stats8 = run(8)
    assert out1 == out8
    assert all(len(out8[i]) == budgets[i] for i in range(len(budgets)))
    # every decode step costs a dispatch at horizon 1; the horizon ladder
    # (8,4,2,1 tail) amortizes >= 3x fewer dispatches even on these tiny
    # budgets (benchmarks/run.py measures the >= 4x per-token drop)
    assert stats1["decode_dispatches"] == stats1["decode_steps"]
    assert stats8["decode_dispatches"] * 3 <= stats1["decode_dispatches"]
    # and the horizon engine syncs once per dispatch, not per token
    assert stats8["device_syncs"] < stats1["device_syncs"]


def test_eos_early_exit_inside_horizon():
    """A request whose EOS fires mid-horizon stops exactly there: the lane
    is masked on device for the rest of the block (no trailing tokens)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # find the 3rd generated token, then use it as EOS with a big budget
    eng0 = ContinuousBatchingEngine(model, params, max_batch=1,
                                    buckets=(16,), decode_horizon=8)
    eng0.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    ref = eng0.run()[0].tokens_out
    eos = ref[2]
    eng = ContinuousBatchingEngine(model, params, max_batch=1,
                                   buckets=(16,), decode_horizon=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    out = eng.run()[0].tokens_out
    assert out == ref[:ref.index(eos) + 1], (out, ref)


def test_wave_engine_stats_and_no_stale_tokens():
    cfg, model, params = _setup()
    eng = WaveEngine(model, params, max_batch=2, buckets=(16,))
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert eng.stats["waves"] == 3  # 2 + 2 + 1
    assert all(len(r.tokens_out) == 3 for r in done)
    assert all(r.t_first_token >= r.t_enqueue for r in done)


@pytest.mark.parametrize("engine_cls", [WaveEngine, ContinuousBatchingEngine])
def test_duplicate_rids_are_served(engine_cls):
    """rid is caller-chosen: Request equality must be identity, or the
    scheduler's pending.remove trips on numpy-array comparison."""
    cfg, model, params = _setup()
    eng = engine_cls(model, params, max_batch=2, buckets=(16,))
    rng = np.random.default_rng(5)
    for _ in range(3):
        eng.submit(Request(rid=0, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=2))
    done = eng.run()
    assert len(done) == 3 and all(r.done for r in done)


def test_no_token_appended_after_done():
    r = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=1)
    r.append_token(5, now=1.0)
    assert r.done and r.t_first_token == r.t_done == 1.0
    with pytest.raises(AssertionError):
        r.append_token(6, now=2.0)


@pytest.mark.parametrize("engine_cls", [WaveEngine, ContinuousBatchingEngine])
def test_eos_stops_request(engine_cls):
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # find what the first generated token will be, then use it as EOS
    logits = model.forward_logits(params, tokens=jnp.asarray([prompt]))
    first = int(jnp.argmax(logits[0, -1]))
    eng = engine_cls(model, params, max_batch=1, buckets=(16,))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=first))
    done = eng.run()
    assert done[0].tokens_out == [first]


def test_request_budget_exceeding_slot_rejected():
    cfg, model, params = _setup()
    eng = ContinuousBatchingEngine(model, params, max_batch=1, buckets=(16,),
                                   max_decode_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(12, np.int32),
                           max_new_tokens=100))


def test_serve_plan_shardings_applied():
    """Acceptance: the engine runs under build_plan(..., mode="serve") and
    its params + persistent slot cache carry the plan's NamedShardings."""
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_mesh

    cfg, model, params = _setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = build_plan(cfg, mesh, jax.eval_shape(lambda: params),
                      mode="serve")
    assert plan.mode == "serve"
    # paged=False: this test isolates plan placement on the dense slot
    # path (the plan+paged composition is covered, with a real multi-
    # device mesh, by tests/test_sharded_serving.py)
    eng = ContinuousBatchingEngine(model, params, max_batch=2,
                                   buckets=(16,), plan=plan, paged=False)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=3))
    done = eng.run()
    assert done[0].done and len(done[0].tokens_out) == 3

    # params placed under the plan's specs
    def walk(specs, arrs):
        if isinstance(specs, dict):
            for k in specs:
                walk(specs[k], arrs[k])
        else:
            assert isinstance(arrs.sharding, NamedSharding)
            assert arrs.sharding.spec == specs, (specs, arrs.sharding.spec)

    walk(plan.param_specs, eng.params)
    # persistent slot cache placed under serve-mode cache specs
    cache_specs = plan.specs_for_caches(
        jax.eval_shape(lambda: eng._slot_caches), batch=eng.max_batch,
        slot_table=True)
    walk(cache_specs, eng._slot_caches)
    # and outputs are unchanged by placement (paged off: the comparison
    # isolates plan placement, so both engines must share the dense path)
    rng = np.random.default_rng(0)
    bare = ContinuousBatchingEngine(model, params, max_batch=2,
                                    buckets=(16,), paged=False)
    bare.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=3))
    assert done[0].tokens_out == bare.run()[0].tokens_out


def test_serve_mode_cache_spec_kv_head_tp():
    """Serve-mode slot layout: k/v shard the kv-head dim over `model`,
    never the slot or seq dims (inserts/writes must stay shard-local)."""
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_abstract_mesh

    cfg, model, _ = _setup()
    mesh = make_abstract_mesh((2, 4), ("data", "model"))
    caches_shape = jax.eval_shape(lambda: model.init_cache(8, 64))
    plan = build_plan(cfg, mesh, None, caches_shape, batch=8, mode="serve")
    slot_specs = plan.specs_for_caches(caches_shape, batch=8,
                                       slot_table=True)

    def walk(specs, shapes, path=(), slot_table=False):
        if isinstance(specs, dict):
            for k in specs:
                walk(specs[k], shapes[k], path + (k,), slot_table)
            return
        name = path[-1]
        off = 1 if "scan" in path else 0
        if slot_table and len(specs) > off:
            # inserts land at traced slot indices: slot dim never sharded
            assert specs[off] is None, (path, specs)
        if name in ("k", "v"):
            # seq dim unsharded; kv-head dim on model iff divisible
            assert specs[off + 1] is None
            nkv = shapes.shape[off + 2]
            if nkv % 4 == 0:
                assert specs[off + 2] == "model"

    walk(plan.cache_specs, caches_shape)
    walk(slot_specs, caches_shape, slot_table=True)


def test_admission_policy_deadline_and_warm_buckets():
    policy = AdmissionPolicy(buckets=(16, 32), lane=8,
                             deadline=AdmissionDeadline(0.05))

    def req(rid, n, t):
        return Request(rid=rid, prompt=np.zeros(n, np.int32), t_arrival=t)

    # all young: warm buckets first, FIFO within
    waiting = [req(0, 20, 0.0), req(1, 5, 0.0), req(2, 6, 0.0)]
    order = policy.select(waiting, 3, warm=[16], now=0.01)
    assert order == [1, 2, 0]  # len 5/6 -> warm bucket 16; len 20 -> cold 32
    # an overdue request beats warm-bucket preference
    waiting = [req(0, 20, 0.0), req(1, 5, 0.06)]
    order = policy.select(waiting, 1, warm=[16], now=0.08)
    assert order == [0]  # waited 80ms > deadline; jumps the warm len-5
    # deadline_s=0 degenerates to strict FIFO
    fifo = AdmissionPolicy(buckets=(16, 32), lane=8,
                           deadline=AdmissionDeadline(0.0))
    waiting = [req(0, 20, 0.0), req(1, 5, 0.0)]
    assert fifo.select(waiting, 2, warm=[16], now=0.0) == [0, 1]


def test_paged_auto_eligibility():
    """paged='auto' turns the arena on for all-attention configs and off
    for recurrent/hybrid ones and under a ClusterPlan; forcing it on an
    ineligible config raises."""
    cfg, model, params = _setup()
    assert ContinuousBatchingEngine(model, params, max_batch=2,
                                    buckets=(16,)).paged
    cfg_r, model_r, params_r = _setup("recurrentgemma-2b")
    eng = ContinuousBatchingEngine(model_r, params_r, max_batch=2,
                                   buckets=(16,))
    assert not eng.paged
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(model_r, params_r, max_batch=2,
                                 buckets=(16,), paged=True)


def test_paged_matches_dense_slots_token_streams(ref_impl):
    """Tentpole acceptance: the paged engine's streams are bit-identical
    to the dense-slot engine's on a mixed stream (one pinned impl; the
    gathered paged layout equals the dense slot layout row for row)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 14, 9, 3, 11, 7, 12, 6)]
    budgets = [3, 8, 1, 6, 2, 7, 4, 5]

    def run(paged):
        eng = ContinuousBatchingEngine(model, params, max_batch=3,
                                       buckets=(16, 32), paged=paged)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=budgets[i]))
        return {r.rid: r.tokens_out for r in eng.run()}, eng

    out_d, _ = run(False)
    out_p, eng = run(True)
    assert out_d == out_p
    # drained engine holds pages only through the radix tree (no leaks)
    assert eng.stats["pages_in_use"] == eng.prefix_cache.cached_pages
    assert eng.stats["admitted"] == eng.stats["completed"] == len(prompts)


def test_prefix_hit_stream_bit_identical_to_cold(ref_impl):
    """Satellite acceptance: a prefix-cache hit (prefill skipped, suffix
    ingested through the forced-token queue) produces a bit-identical
    token stream to a cold prefill of the same prompt."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(0, cfg.vocab_size, 35).astype(np.int32)

    def reqs():
        out = []
        for i in range(4):
            tail = np.random.default_rng(100 + i).integers(
                0, cfg.vocab_size, 4).astype(np.int32)
            out.append(Request(rid=i,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new_tokens=5))
        return out

    cold = ContinuousBatchingEngine(model, params, max_batch=2,
                                    buckets=(48,), paged=False,
                                    max_decode_len=16)
    for r in reqs():
        cold.submit(r)
    out_cold = {r.rid: r.tokens_out for r in cold.run()}

    warm = ContinuousBatchingEngine(model, params, max_batch=2,
                                    buckets=(48,), max_decode_len=16)
    for r in reqs():
        warm.submit(r)
    out_warm = {r.rid: r.tokens_out for r in warm.run()}
    assert out_cold == out_warm
    # every admission after the first rode the radix cache (35 tokens
    # cover 2 full 16-token pages) and skipped its prefill
    assert warm.stats["prefix_hits"] == 3
    assert warm.stats["prefix_hit_tokens"] == 3 * 32
    assert warm.stats["prefills"] == 1
    # a second identical batch is all hits (prompt pages stayed cached)
    for r in reqs():
        warm.submit(r)
    out_again = {r.rid: r.tokens_out for r in warm.run()}
    assert out_again == out_cold
    assert warm.stats["prefix_hits"] == 7


def test_paged_preemption_no_slot_or_page_leak(ref_impl):
    """Preempt-to-free: with a pool sized for ~one request, deadline
    pressure preempts the running lane, the victim is re-queued and still
    completes with its full budget, and no slots or pages leak."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(6)
    # pool: 8 usable pages of 4; each request needs ~5 pages, so two can
    # never run together — the second arrival must starve, then preempt
    eng = ContinuousBatchingEngine(
        model, params, max_batch=2, buckets=(8, 16), max_decode_len=8,
        page_size=4, num_pages=9, deadline_s=0.0)
    prompts = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
    done = eng.run()
    assert len(done) == 3 and all(r.done for r in done)
    assert all(len(r.tokens_out) == 8 for r in done)
    assert eng.stats["completed"] == 3
    assert eng.stats["preemptions"] >= 1
    assert all(p is None for p in eng._lane_pages)
    assert eng.stats["pages_in_use"] == eng.prefix_cache.cached_pages
    # preempted work is never lost: admissions >= requests, tokens exact
    assert eng.stats["admitted"] >= 3


def test_paged_pool_gates_admission(ref_impl):
    """Admission is page-aware: a pool of 6 usable pages holds at most two
    3-page requests at once, the third waits for pages (or preempts), and
    everyone still completes with exact budgets."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(8)
    eng = ContinuousBatchingEngine(
        model, params, max_batch=2, buckets=(8,), max_decode_len=8,
        page_size=4, num_pages=7)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3 and all(len(r.tokens_out) == 4 for r in done)
    assert eng.stats["pages_peak"] <= 6
    assert eng.stats["active_lane_steps"] <= 2 * eng.stats["decode_steps"]


def test_paged_submit_rejects_oversized_request():
    cfg, model, params = _setup()
    eng = ContinuousBatchingEngine(model, params, max_batch=1,
                                   buckets=(16,), max_decode_len=16,
                                   page_size=4, num_pages=5)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(12, np.int32),
                           max_new_tokens=8))


def test_poisson_arrivals_pace_admission():
    """Requests are admitted no earlier than their arrival offset."""
    cfg, model, params = _setup()
    eng = ContinuousBatchingEngine(model, params, max_batch=2, buckets=(16,))
    rng = np.random.default_rng(4)
    offsets = [0.0, 0.05, 0.30]
    t0 = None
    for i, dt in enumerate(offsets):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=2,
            t_arrival=dt))
    import time
    t0 = time.perf_counter()
    done = eng.run()
    assert len(done) == 3
    for r, dt in zip(done, offsets):
        assert r.t_admitted - t0 >= dt - 1e-3, (r.rid, r.t_admitted - t0, dt)

# ---------------------------------------------------------------------------
# speculative decoding (engine level) + preemption-cascade damping
# ---------------------------------------------------------------------------


def _spec_draft(cfg, model, params, kind):
    if kind == "self":
        # the target drafting for itself: acceptance exactly 1.0
        return model, params
    import dataclasses
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    draft = make_model(dcfg, remat=False)
    # random init: near-zero acceptance, every block falls back to the
    # target's own argmax
    return draft, init_params(dcfg, jax.random.PRNGKey(7))


# two cases instead of the full draft_kind x kv_dtype product: each case
# compiles its own spec + plain engine programs (~5 min on CPU), and the
# dtype axis is orthogonal to the engine plumbing under test here (the
# arena-dtype x k sweep lives in test_spec_decode.py) — so cover both
# draft kinds and both dtypes diagonally
@pytest.mark.parametrize("draft_kind,kv_dtype", [
    ("random", "bf16"), ("self", "int8"),
])
def test_spec_engine_streams_bit_identical(draft_kind, kv_dtype, ref_impl):
    """Speculative serving is lossless end to end: on a shared-prefix
    stream (so admissions mix cold prefills and prefix hits whose suffix
    rides the forced queue) the spec engine's streams equal the plain
    paged engine's for a 1.0-acceptance draft AND a ~0-acceptance draft,
    on bf16 and int8 arenas, with no draft-arena page leaks."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, cfg.vocab_size, 18).astype(np.int32)

    def reqs():
        out = []
        for i in range(6):
            tail = np.random.default_rng(200 + i).integers(
                0, cfg.vocab_size, 3 + i % 3).astype(np.int32)
            out.append(Request(rid=i,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new_tokens=6 + (i % 4)))
        return out

    kw = dict(max_batch=2, buckets=(32,), max_decode_len=24, page_size=4,
              kv_dtype=kv_dtype)
    plain = ContinuousBatchingEngine(model, params, **kw)
    for r in reqs():
        plain.submit(r)
    out_plain = {r.rid: r.tokens_out for r in plain.run()}

    draft, dparams = _spec_draft(cfg, model, params, draft_kind)
    spec = ContinuousBatchingEngine(
        model, params, spec_config=dict(draft_model=draft,
                                        draft_params=dparams, spec_k=4),
        **kw)
    for r in reqs():
        spec.submit(r)
    out_spec = {r.rid: r.tokens_out for r in spec.run()}
    assert out_plain == out_spec
    assert spec.stats["prefix_hits"] > 0
    assert spec.stats["spec_dispatches"] > 0
    if draft_kind == "self":
        # a perfect draft never diverges, but `proposed` counts k per lane
        # per dispatch while a lane whose budget/EOS lands mid-block leaves
        # the tail of its proposal unconsumed — so the acceptance rate is
        # high, not exactly 1
        assert spec.stats["spec_accepted"] > 0
        assert (spec.stats["spec_accepted"]
                >= 0.6 * spec.stats["spec_proposed"])
    # drained: no lane holds draft pages, draft pool fully returned
    spec.kv.assert_drained()


def test_spec_engine_sharded_streams_bit_identical(ref_impl):
    """Speculative decoding composes with the serve plan: draft params and
    draft arena replicate, verify queries ride the gather-form TP paged
    path — streams must equal the single-device plain engine's."""
    import dataclasses as _dc

    if jax.device_count() < 2:
        pytest.skip("needs >1 device (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_mesh

    cfg = _dc.replace(get_config("smollm-135m").reduced(),
                      n_heads=8, n_kv_heads=8)
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 8, 14)]

    def run(plan, spec_config):
        eng = ContinuousBatchingEngine(
            model, params, max_batch=2, buckets=(16,), max_decode_len=16,
            page_size=4, plan=plan, spec_config=spec_config)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        return {r.rid: r.tokens_out for r in eng.run()}

    ref = run(None, None)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    plan = build_plan(cfg, mesh, mode="serve")
    draft, dparams = _spec_draft(cfg, model, params, "random")
    assert run(plan, dict(draft_model=draft, draft_params=dparams,
                          spec_k=2)) == ref
    # and the 1.0-acceptance path under the same plan
    assert run(plan, dict(draft_model=model, draft_params=params,
                          spec_k=2)) == ref


def test_preemption_budget_stops_cascade(ref_impl):
    """Preemption-cascade damping: with a pool that fits only one request
    and zero deadline slack, hot shared-prefix arrivals would evict the
    same victim forever (it re-enters the queue, hits the warm prefix,
    re-admits, and is evicted again).  The per-request preemption budget
    caps the loop: an over-budget victim is exempt from victim() and
    jumps the admission order, so every request completes and nobody is
    preempted more than `preempt_budget` times."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    # 6 usable pages: a cold 9+8-token request takes 5, and even a
    # prefix-hit follower (2 shared + 3 own) finds only 1 free — every
    # admission beyond the first must preempt
    eng = ContinuousBatchingEngine(
        model, params, max_batch=2, buckets=(8, 16), max_decode_len=8,
        page_size=4, num_pages=7, deadline_s=0.0)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=shared.copy(), max_new_tokens=8))
    done = eng.run()
    assert len(done) == 4 and all(len(r.tokens_out) == 8 for r in done)
    assert eng.stats["preemptions"] >= 1
    assert all(r.n_preempts <= eng.sched.preempt_budget for r in done), \
        [(r.rid, r.n_preempts) for r in done]
    # no slot or page leaked through the churn
    assert all(p is None for p in eng._lane_pages)
    assert eng.stats["pages_in_use"] == eng.prefix_cache.cached_pages
