"""GMI collectives + pipeline + compressed psum on 8 simulated devices.

Multi-device tests run in a subprocess (XLA_FLAGS must be set before jax
init and must NOT leak into the 1-device test session, per the brief).
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import gmi
from repro.core.pipeline import shard_map_compat
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("pod", "data"))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) + 1.0
def run(fn, in_spec, out_spec):
    return shard_map_compat(fn, mesh=mesh, in_specs=(in_spec,),
                            out_specs=out_spec)
"""


def test_gmi_primitives_and_composition():
    _run(PRELUDE + """
# broadcast: every member sees root's shard
y = run(lambda v: gmi.broadcast(v, "data", root=2),
        P(("pod", "data")), P("pod", None))(x)
assert np.allclose(np.asarray(y)[0], np.asarray(x)[2])
assert np.allclose(np.asarray(y)[1], np.asarray(x)[6])

# reduce: only root holds the sum
r = run(lambda v: gmi.reduce(v, "data", root=1), P(("pod","data")), P(("pod","data")))(x)
r = np.asarray(r)
assert np.allclose(r[1], np.asarray(x)[:4].sum(0))
assert np.allclose(r[0], 0) and np.allclose(r[2], 0)

# scatter: member i of the group receives slice i of root's (4,...) value
# (per-member value is a (4,) row; the out_spec stacks them -> (32,))
s_in = jnp.arange(4 * 4, dtype=jnp.float32).reshape(4, 4)
sc = run(lambda v: gmi.scatter(v, "data", root=0), P(), P(("pod","data")))(s_in)
assert np.allclose(np.asarray(sc).reshape(8, 4),
                   np.concatenate([np.asarray(s_in)] * 2, 0))

# composed == fused (paper: AllGather = Gather -> Broadcast, etc.)
a1 = run(lambda v: gmi.allreduce_composed(v, "data"), P(("pod","data")), P("pod", None))(x)
a2 = run(lambda v: gmi.allreduce(v, "data"), P(("pod","data")), P("pod", None))(x)
assert np.allclose(np.asarray(a1), np.asarray(a2))
g1 = run(lambda v: gmi.allgather_composed(v, "data"), P(("pod","data")), P("pod", None, None))(x)
g2 = run(lambda v: gmi.allgather(v, "data"), P(("pod","data")), P("pod", None, None))(x)
assert np.allclose(np.asarray(g1), np.asarray(g2))
print("OK")
""")


def test_hierarchical_gateway_allreduce():
    _run(PRELUDE + """
# hierarchical (gateway) == flat; and cluster_send rotates along pods
h1 = run(lambda v: gmi.hier_allreduce(v, "data", "pod"), P(("pod","data")), P(None))(x)
h2 = run(lambda v: gmi.flat_allreduce(v, "data", "pod"), P(("pod","data")), P(None))(x)
assert np.allclose(np.asarray(h1), np.asarray(h2))

snd = run(lambda v: gmi.cluster_send(v, "pod"), P("pod", None), P("pod", None))(x)
assert np.allclose(np.asarray(snd)[:4], np.asarray(x)[4:])
assert np.allclose(np.asarray(snd)[4:], np.asarray(x)[:4])
print("OK")
""")


def test_compressed_psum_close_to_exact():
    _run(PRELUDE + """
from repro.optim.compression import compressed_psum
g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 64)).astype(np.float32))
exact = run(lambda v: jax.lax.psum(v, "pod"), P(("pod","data")), P(("pod","data")))(g)
approx = run(lambda v: compressed_psum(v, "pod"), P(("pod","data")), P(("pod","data")))(g)
err = np.abs(np.asarray(exact) - np.asarray(approx))
scale = np.abs(np.asarray(exact)).max()
assert err.max() <= 2 * scale / 127 + 1e-6, err.max()
print("OK")
""")


def test_pipeline_matches_sequential():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.pipeline import pipelined_apply, pipeline_steps
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("stage",))
w = jnp.asarray(np.random.default_rng(0).normal(0, 0.5, (4, 8, 8)).astype(np.float32))
xm = jnp.asarray(np.random.default_rng(1).normal(0, 1, (6, 2, 8)).astype(np.float32))
out = pipelined_apply(lambda p, v: jnp.tanh(v @ p), mesh, "stage", w, xm)
ref = xm
for s in range(4):
    ref = jnp.tanh(ref @ w[s])
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
assert pipeline_steps(6, 4) == 9
print("OK")
""")
