"""No-padding packing invariants (paper §7.1), incl. hypothesis properties."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback draws (see detshim.py)
    from detshim import given, settings
    import detshim as st

from repro.core.packing import bucket_len, pack_sequences, padded_batch


@given(st.lists(st.integers(1, 100), min_size=1, max_size=40),
       st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_pack_preserves_all_tokens(lengths, seed):
    rng = np.random.default_rng(seed)
    seqs = [rng.integers(1, 1000, n).astype(np.int32) for n in lengths]
    row = max(lengths)
    packed = pack_sequences(seqs, row)
    assert packed.n_segments == len(seqs)
    # every sequence appears contiguously with positions 0..n-1
    recovered = {}
    for r in range(packed.tokens.shape[0]):
        for c in range(row):
            sid = packed.segment_ids[r, c]
            if sid >= 0:
                recovered.setdefault(sid, []).append(
                    (packed.positions[r, c], packed.tokens[r, c]))
    assert len(recovered) == len(seqs)
    recovered_sorted = sorted(
        (sorted(v) for v in recovered.values()),
        key=lambda kv: (len(kv), [t for _, t in kv]))
    originals = sorted(
        ([(i, t) for i, t in enumerate(s)] for s in seqs),
        key=lambda kv: (len(kv), [t for _, t in kv]))
    for a, b in zip(recovered_sorted, originals):
        assert [t for _, t in a] == [t for _, t in b]
        assert [p for p, _ in a] == list(range(len(a)))


@given(st.lists(st.integers(1, 64), min_size=2, max_size=30))
@settings(max_examples=40, deadline=None)
def test_pack_beats_padding(lengths):
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, 99, n).astype(np.int32) for n in lengths]
    row = 64
    packed = pack_sequences(seqs, row)
    padded = padded_batch(seqs, row)
    assert packed.tokens.shape[0] <= padded.tokens.shape[0]
    assert packed.utilization >= padded.utilization - 1e-9


def test_bucket_len_minimum_padding():
    assert bucket_len(54, buckets=(32, 64, 128)) == 64  # MRPC avg from paper
    assert bucket_len(128, buckets=(32, 64, 128)) == 128
    assert bucket_len(130) == 256
    assert bucket_len(1, buckets=()) == 128  # one MXU lane tile
