"""Int8 KV-cache pages: quantization bounds, kernel/oracle equality, and
engine-level accuracy.

Kernel bar: for random page tables — including pages *shared between lanes*
(radix prefix reuse), whose scales are shared by construction because they
live in the arena — the quantized Pallas kernel (interpret mode, real body),
the quantized jnp oracle, and the dense decode oracle over the explicitly
gathered-and-dequantized cache all agree; the last pair *bitwise*.

Quantization bar: `kv_quantize` round-trips within half a quantization step
per element (round-half-away symmetric int8), per cache row per kv head.

Engine bar: a `kv_dtype="int8"` engine serves greedy streams that agree
with the bf16 engine on >= 99% of tokens — measured on a model fitted to a
confident synthetic task (models/synthetic.py), because stream agreement on
a random-init model measures bf16 tie-breaking, not quantization — and an
int8 prefix-cache hit (shared quantized pages + shared scales) is
bit-identical to the int8 cold-prefill stream.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback draws (see detshim.py)
    from detshim import given, settings
    import detshim as st

from repro.core.quant import kv_dequantize, kv_quantize
from repro.kernels import ops

SENTINEL = 2 ** 30


# ---------------------------------------------------------------------------
# quantize -> dequantize round trip
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_kv_quantize_round_trip_error_bound(seed):
    """|x - dequant(quantize(x))| <= scale/2 elementwise (round half away),
    with one scale per row per kv head and full int8 range use."""
    rng = np.random.default_rng(seed)
    shape = (3, 5, 2, 16)  # (pages, ps, KVH, hd)-shaped rows
    x = jnp.asarray(rng.normal(0, rng.uniform(0.1, 4.0), shape), jnp.float32)
    q, s = kv_quantize(x)
    assert q.dtype == jnp.int8 and s.shape == shape[:-1]
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    err = np.abs(np.asarray(kv_dequantize(q, s)) - np.asarray(x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-7
    assert (err <= bound).all(), float((err - bound).max())
    # scales are per row: every row's amax maps to |q| == 127 exactly
    amax_rows = np.abs(np.asarray(q)).max(-1)
    np.testing.assert_array_equal(amax_rows, 127)


def test_kv_quantize_bf16_input_and_zero_rows():
    """bf16 rows quantize through f32; all-zero rows give the eps scale
    (never a div-by-zero) and dequantize to exact zeros."""
    x = jnp.zeros((2, 4, 8), jnp.bfloat16)
    q, s = kv_quantize(x)
    assert (np.asarray(q) == 0).all() and (np.asarray(s) > 0).all()
    np.testing.assert_array_equal(np.asarray(kv_dequantize(q, s)), 0.0)


# ---------------------------------------------------------------------------
# quantized paged flash-decode vs oracles
# ---------------------------------------------------------------------------


def _mk_paged_q(rng, b, h, kvh, hd, n_pages, ps, maxp, share=True):
    """Random *quantized* arena + per-lane tables; lanes may share table
    entries, and a shared page's scales are shared automatically (they are
    arena planes indexed through the same table)."""
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd)), jnp.float32) * (hd ** -0.5)
    kf = jnp.asarray(rng.normal(0, 1, (n_pages, ps, kvh, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(0, 1, (n_pages, ps, kvh, hd)), jnp.float32)
    k8, ks = kv_quantize(kf)
    v8, vs = kv_quantize(vf)
    kpos = np.full((n_pages, ps), SENTINEL, np.int64)
    pt = np.zeros((b, maxp), np.int32)
    next_page = 1  # page 0 = trash (all sentinel)
    shared = {}
    for lane in range(b):
        for j in range(maxp):
            if share and j in shared and rng.random() < 0.5:
                pt[lane, j] = shared[j]  # prefix page shared across lanes
            else:
                page = next_page
                next_page += 1
                assert page < n_pages
                pt[lane, j] = page
                shared.setdefault(j, page)
                kpos[page] = j * ps + np.arange(ps)
    qpos = jnp.asarray(rng.integers(ps, maxp * ps, b), jnp.int32)
    return (q, k8, v8, ks, vs, jnp.asarray(kpos, jnp.int32),
            jnp.asarray(pt), qpos)


@given(st.integers(0, 10_000), st.sampled_from([(4, 4), (8, 2), (6, 3)]),
       st.sampled_from([(8, 3), (16, 2), (8, 5)]))
@settings(max_examples=12, deadline=None)
def test_paged_decode_q_interpret_matches_ref(seed, heads, paging):
    """Quantized Pallas kernel (interpret) == dequantizing gather oracle,
    cross-lane shared pages (shared scales) included."""
    h, kvh = heads
    ps, maxp = paging
    rng = np.random.default_rng(seed)
    b, hd = 3, 16
    n_pages = 1 + b * maxp + 1
    q, k8, v8, ks, vs, kpos, pt, qpos = _mk_paged_q(
        rng, b, h, kvh, hd, n_pages, ps, maxp)
    got = ops.paged_flash_decode_q(q, k8, v8, ks, vs, kpos, pt, qpos,
                                   impl="interpret")
    want = ops.paged_flash_decode_q(q, k8, v8, ks, vs, kpos, pt, qpos,
                                    impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_paged_q_ref_bitwise_equals_dense_ref_on_dequant(seed):
    """Gathering the int8 pages, dequantizing with their arena scales and
    running the dense decode oracle is *bitwise* what the quantized paged
    oracle computes — the property int8 engine-stream comparisons stand
    on."""
    rng = np.random.default_rng(seed)
    b, h, kvh, hd, ps, maxp = 2, 4, 2, 16, 8, 4
    n_pages = 1 + b * maxp
    q, k8, v8, ks, vs, kpos, pt, qpos = _mk_paged_q(
        rng, b, h, kvh, hd, n_pages, ps, maxp)
    paged = ops.paged_flash_decode_q(q, k8, v8, ks, vs, kpos, pt, qpos,
                                     impl="ref")
    ptn = np.asarray(pt)
    kg = jnp.asarray(np.asarray(kv_dequantize(k8, ks))[ptn].reshape(
        b, -1, kvh, hd))
    vg = jnp.asarray(np.asarray(kv_dequantize(v8, vs))[ptn].reshape(
        b, -1, kvh, hd))
    kpg = jnp.asarray(np.asarray(kpos)[ptn].reshape(b, -1))
    dense = ops.flash_decode(q, kg, vg, kpg, qpos, impl="ref")
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_paged_decode_q_inactive_and_sentinel_rows():
    """Inactive lanes and all-sentinel (never written / trash) pages give
    exact zeros, never NaN, in both impls — zero scales on never-written
    pages must not poison anything."""
    rng = np.random.default_rng(7)
    b, h, kvh, hd, ps, maxp = 3, 4, 2, 16, 8, 3
    q, k8, v8, ks, vs, kpos, pt, qpos = _mk_paged_q(
        rng, b, h, kvh, hd, 1 + b * maxp, ps, maxp)
    pt = pt.at[2].set(0)  # lane 2's whole table points at the trash page
    active = jnp.asarray([True, False, True])
    for impl in ("ref", "interpret"):
        out = np.asarray(ops.paged_flash_decode_q(
            q, k8, v8, ks, vs, kpos, pt, qpos, active=active, impl=impl))
        assert not np.isnan(out).any(), impl
        np.testing.assert_array_equal(out[1], 0.0)  # inactive
        np.testing.assert_array_equal(out[2], 0.0)  # all-sentinel pages


def test_paged_decode_q_trash_page_garbage_is_unreachable():
    """Garbage int8 values and scales in the trash page (inactive lanes
    scatter there) must not perturb live lanes while its kpos stay
    sentinel."""
    rng = np.random.default_rng(11)
    b, h, kvh, hd, ps, maxp = 2, 4, 2, 16, 8, 3
    q, k8, v8, ks, vs, kpos, pt, qpos = _mk_paged_q(
        rng, b, h, kvh, hd, 1 + b * maxp, ps, maxp)
    clean = ops.paged_flash_decode_q(q, k8, v8, ks, vs, kpos, pt, qpos,
                                     impl="ref")
    dirty = ops.paged_flash_decode_q(
        q, k8.at[0].set(127), v8.at[0].set(-127),
        ks.at[0].set(1e9), vs.at[0].set(1e9), kpos, pt, qpos, impl="ref")
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


# ---------------------------------------------------------------------------
# quantized cache trees (admission path)
# ---------------------------------------------------------------------------


def test_quantize_kv_tree_matches_rowwise_quantization():
    """The admission-path bulk conversion applies exactly the per-row rule
    the decode scatter applies token-by-token — the invariant that keeps
    prefix-hit suffix ingest bit-identical to cold prefill under int8."""
    from repro.models.transformer import cache_is_quantized, quantize_kv_tree

    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(0, 1, (1, 6, 2, 8)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 6, 2, 8)), jnp.bfloat16)
    kpos = jnp.arange(6, dtype=jnp.int32)[None]
    tree = {"scan": {"b0": {"k": k, "v": v, "kpos": kpos}}, "tail": {}}
    out = quantize_kv_tree(tree)
    leaf = out["scan"]["b0"]
    assert set(leaf) == {"k", "v", "k_scale", "v_scale", "kpos"}
    kq, ks = kv_quantize(k)
    np.testing.assert_array_equal(np.asarray(leaf["k"]), np.asarray(kq))
    np.testing.assert_array_equal(np.asarray(leaf["k_scale"]),
                                  np.asarray(ks))
    np.testing.assert_array_equal(np.asarray(leaf["kpos"]),
                                  np.asarray(kpos))
    assert cache_is_quantized(out) and not cache_is_quantized(tree)


# ---------------------------------------------------------------------------
# engine: int8 KV serving
# ---------------------------------------------------------------------------


def _fitted_setup():
    from repro.configs import get_config
    from repro.models.synthetic import fit_affine_lm
    from repro.models.transformer import make_model

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = fit_affine_lm(model)  # cached across tests in this process
    return cfg, model, params


@pytest.fixture
def ref_impl():
    from repro.kernels import ops as kops
    with kops.pinned_impl("ref"):
        yield


def _run_engine(model, params, prompts, budgets, kv_dtype, **kw):
    from repro.serving.engine import ContinuousBatchingEngine, Request

    eng = ContinuousBatchingEngine(model, params, max_batch=4,
                                   buckets=(16, 32), kv_dtype=kv_dtype, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=budgets[i]))
    done = eng.run()
    return {r.rid: r.tokens_out for r in done}, eng


def test_int8_engine_matches_bf16_streams_99pct(ref_impl):
    """Acceptance: kv_dtype='int8' and bf16 greedy streams agree on >=99%
    of tokens for an in-distribution workload on the fitted model."""
    from repro.models.synthetic import affine_prompts

    cfg, model, params = _fitted_setup()
    rng = np.random.default_rng(5)
    prompts = affine_prompts(rng, 10, cfg.vocab_size)
    budgets = [int(b) for b in rng.integers(8, 24, len(prompts))]
    bf, _ = _run_engine(model, params, prompts, budgets, "bf16")
    i8, eng = _run_engine(model, params, prompts, budgets, "int8")
    assert eng.kv_dtype == "int8" and eng.paged
    tot = sum(len(v) for v in bf.values())
    matched = sum(sum(a == b for a, b in zip(bf[r], i8[r])) for r in bf)
    assert all(len(i8[r]) == budgets[r] for r in i8)
    assert matched / tot >= 0.99, (matched, tot)


def test_int8_prefix_hit_bit_identical_to_cold(ref_impl):
    """A prefix-cache hit on *quantized* pages (shared int8 values AND
    shared arena scales) must produce the identical stream a cold int8
    prefill produces — the int8 analogue of the PR 3 bit-identity bar."""
    from repro.serving.engine import ContinuousBatchingEngine, Request

    cfg, model, params = _fitted_setup()
    rng = np.random.default_rng(9)
    t0, step = int(rng.integers(0, cfg.vocab_size)), 5
    prefix = ((t0 + step * np.arange(16)) % cfg.vocab_size).astype(np.int32)
    tails = [((prefix[-1] + step * np.arange(1, 4 + i)) % cfg.vocab_size)
             .astype(np.int32) for i in range(3)]
    prompts = [np.concatenate([prefix, t]) for t in tails]

    def serve(batch):
        eng = ContinuousBatchingEngine(model, params, max_batch=batch,
                                       buckets=(32,), kv_dtype="int8",
                                       page_size=8)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        return {r.rid: r.tokens_out for r in eng.run()}, eng

    # batch=1: sequential admissions -> later prompts hit the first's pages
    hit, eng_hit = serve(1)
    assert eng_hit.stats["prefix_hits"] >= 1
    # fresh engine per prompt: every admission is a cold prefill
    cold = {}
    for i, p in enumerate(prompts):
        eng = ContinuousBatchingEngine(model, params, max_batch=1,
                                       buckets=(32,), kv_dtype="int8",
                                       page_size=8)
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        cold.update({r.rid: r.tokens_out for r in eng.run()})
    assert hit == cold


def test_int8_requires_paged_pool():
    """kv_dtype='int8' on a dense-slot fallback (e.g. recurrent model)
    must fail loudly, not silently serve bf16 slots."""
    from repro.configs import get_config
    from repro.models.transformer import init_params, make_model
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = get_config("recurrentgemma-2b").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="int8"):
        ContinuousBatchingEngine(model, params, max_batch=2,
                                 buckets=(16,), kv_dtype="int8")


def test_kv_page_bytes_int8_buys_more_pages():
    from repro.configs import get_config
    from repro.serving.engine import kv_page_bytes

    cfg = get_config("smollm-135m").reduced()
    b16 = kv_page_bytes(cfg, 16, "bf16")
    i8 = kv_page_bytes(cfg, 16, "int8")
    assert i8 < b16
    hd = cfg.head_dim
    assert i8 / b16 == pytest.approx((hd + 4) / (2 * hd))


def test_quant_weights_engine_serves_and_matches(ref_impl):
    """W8A8 weight path: the engine serves to budget and the streams stay
    >=99% aligned with the bf16-weight engine on the fitted model; with
    kv_dtype='int8' on top, the decode loop is integer-dominant."""
    from repro.models.synthetic import affine_prompts

    cfg, model, params = _fitted_setup()
    rng = np.random.default_rng(13)
    prompts = affine_prompts(rng, 6, cfg.vocab_size)
    budgets = [int(b) for b in rng.integers(6, 14, len(prompts))]
    bf, _ = _run_engine(model, params, prompts, budgets, "bf16")
    qq, eng = _run_engine(model, params, prompts, budgets, "int8",
                          quant_weights=True)
    assert eng.quant_weights
    assert all(len(qq[r]) == budgets[r] for r in qq)
    tot = sum(len(v) for v in bf.values())
    matched = sum(sum(a == b for a, b in zip(bf[r], qq[r])) for r in bf)
    assert matched / tot >= 0.99, (matched, tot)
