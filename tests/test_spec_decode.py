"""Speculative decoding: lossless greedy verification.

Correctness bar, per ISSUE 6: speculative token streams are BIT-IDENTICAL
to `Model.decode_steps` streams for every speculation depth, acceptance
rate, and KV numeric — acceptance only moves *throughput*, never a token.
Plus the acceptance state machine's unit semantics and the KV manager's
dual-arena (draft + target) page accounting.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import init_params, make_model, spec_acceptance
from repro.serving.kv_manager import KVManager, kv_page_bytes, spec_pool_split


@pytest.fixture
def ref_impl():
    """Pin the kernel impl to the jnp oracle: spec-vs-sequential equality
    needs prefill/decode/verify to share one summation order (the reduced
    configs' bf16 logits carry exact top-2 ties)."""
    from repro.kernels import ops
    prev = ops._IMPL
    ops.set_impl("ref")
    yield
    ops._IMPL = prev


# ---------------------------------------------------------------------------
# spec_acceptance unit semantics
# ---------------------------------------------------------------------------


def _accept(ins, tgt, active=None, rem=None, eos=None, forced=None,
            flen=None, fptr=None, pad=0):
    ins = jnp.asarray(ins, jnp.int32)
    tgt = jnp.asarray(tgt, jnp.int32)
    b = ins.shape[0]
    active = (jnp.ones((b,), bool) if active is None
              else jnp.asarray(active, bool))
    rem = (jnp.full((b,), 100, jnp.int32) if rem is None
           else jnp.asarray(rem, jnp.int32))
    eos = (jnp.full((b,), -1, jnp.int32) if eos is None
           else jnp.asarray(eos, jnp.int32))
    forced = (jnp.zeros((b, 1), jnp.int32) if forced is None
              else jnp.asarray(forced, jnp.int32))
    flen = (jnp.zeros((b,), jnp.int32) if flen is None
            else jnp.asarray(flen, jnp.int32))
    fptr = (jnp.zeros((b,), jnp.int32) if fptr is None
            else jnp.asarray(fptr, jnp.int32))
    out = spec_acceptance(ins, tgt, active, rem, eos, pad, forced, flen,
                          fptr)
    return [np.asarray(x) for x in out]


def test_acceptance_full_and_divergent():
    # lane 0: drafted inputs all match the target's argmaxes -> every
    # position emits and the bonus token (tgt[-1]) becomes the next input;
    # lane 1: ins[2] != tgt[1] -> steps 0-1 emit, step 2 is a hole, and
    # the correction token tgt[1] (already emitted) becomes the next input
    emit, cur, alive, rem, fptr, v = _accept(
        ins=[[5, 10, 11], [5, 20, 99]],
        tgt=[[10, 11, 12], [20, 21, 22]])
    assert emit.T.tolist() == [[10, 11, 12], [20, 21, -1]]
    assert cur.tolist() == [12, 21]
    assert v.tolist() == [3, 2]
    assert alive.tolist() == [True, True]
    assert rem.tolist() == [97, 98]


def test_acceptance_eos_and_budget_exit():
    # lane 0 emits its EOS at step 1 -> step 2 is a hole, lane dead, pad
    # fed; lane 1 has budget 1 -> emits once then exits
    emit, cur, alive, rem, fptr, v = _accept(
        ins=[[5, 10, 7], [5, 20, 21]],
        tgt=[[10, 7, 12], [20, 21, 22]],
        rem=[100, 1], eos=[7, -1], pad=0)
    assert emit.T.tolist() == [[10, 7, -1], [20, -1, -1]]
    assert alive.tolist() == [False, False]
    assert cur.tolist() == [0, 0]
    # the dead lanes consumed exactly the steps that ran
    assert v.tolist() == [2, 1]


def test_acceptance_forced_queue_swallows_then_emits():
    # forced queue covers 2 pending positions: steps 0-1 are swallowed
    # prompt ingest (emit -1, budget untouched), step 2 emits the first
    # generated token; forced inputs are always "matched" (not drafted)
    emit, cur, alive, rem, fptr, v = _accept(
        ins=[[5, 8, 9]], tgt=[[50, 51, 52]],
        forced=[[8, 9, 0, 0]], flen=[2], fptr=[0], rem=[10])
    assert emit.T.tolist() == [[-1, -1, 52]]
    assert v.tolist() == [3]
    assert fptr.tolist() == [2]
    assert rem.tolist() == [9]
    assert cur.tolist() == [52]


def test_acceptance_inactive_lane_untouched():
    emit, cur, alive, rem, fptr, v = _accept(
        ins=[[5, 6, 7]], tgt=[[1, 2, 3]], active=[False], pad=0)
    assert emit.T.tolist() == [[-1, -1, -1]]
    assert v.tolist() == [0]
    assert not alive[0] and cur[0] == 0


# ---------------------------------------------------------------------------
# model-level bit-identity property: spec == decode_steps
# ---------------------------------------------------------------------------


def _spec_setup(draft_kind):
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if draft_kind == "self":
        # the target drafting for itself: acceptance is exactly 1.0, the
        # full-acceptance + bonus-token path every dispatch
        return cfg, model, params, model, params
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    draft = make_model(dcfg, remat=False)
    # random init: near-zero acceptance, the all-rejected fallback path
    dparams = init_params(dcfg, jax.random.PRNGKey(1))
    return cfg, model, params, draft, dparams


def _run_spec_vs_sequential(model, params, draft, dparams, k, kv_dtype,
                            budgets=(9, 6)):
    """Drive spec_decode_step to drain and compare against decode_steps,
    ingesting a 3-token prompt through the forced queue on both paths."""
    b, ps, maxp = 2, 4, 16
    num_pages = b * 8 + 1
    prompt = np.array([[5, 7, 9], [11, 3, 2]], np.int32)
    pt = np.zeros((b, maxp), np.int32)
    pt[0, :8] = np.arange(1, 9)
    pt[1, :8] = np.arange(9, 17)

    def fresh(m):
        c = m.init_paged_cache(b, num_pages, ps, maxp, kv_dtype)
        return dict(c, pt=jnp.asarray(pt))

    forced = np.zeros((b, 32), np.int32)
    forced[:, :2] = prompt[:, 1:]
    forced = jnp.asarray(forced)
    flen = jnp.full((b,), 2, jnp.int32)
    token = jnp.asarray(prompt[:, 0])
    active = jnp.ones((b,), bool)
    eos = jnp.full((b,), -1, jnp.int32)
    budget = jnp.asarray(budgets, jnp.int32)

    toks_ref, *_ = model.decode_steps(
        params, fresh(model), token, active, 16, eos_id=eos, budget=budget,
        forced=forced, forced_len=flen, forced_ptr=jnp.zeros((b,), jnp.int32))
    toks_ref = np.asarray(toks_ref)
    ref = [[int(t) for t in toks_ref[:, lane] if t >= 0] for lane in range(b)]

    st_c, st_d = fresh(model), fresh(draft)
    cur, act, rem = token, active, budget
    fptr = jnp.zeros((b,), jnp.int32)
    out = [[] for _ in range(b)]
    for _ in range(24):
        toks, cur, act, rem, fptr, st_c, st_d, _ = model.spec_decode_step(
            params, st_c, cur, act, k, draft, dparams, st_d, eos_id=eos,
            budget=rem, forced=forced, forced_len=flen, forced_ptr=fptr)
        tb = np.asarray(toks)
        for lane in range(b):
            out[lane].extend(int(t) for t in tb[:, lane] if t >= 0)
        if not bool(np.asarray(act).any()):
            break
    assert out == ref, (out, ref)


# trimmed cross-product (each case compiles its own spec programs and
# costs ~1-2 min on CPU): every k in {1,2,4,8}, both draft kinds and both
# arena dtypes appear, with int8 paired against the cases bf16 skips
@pytest.mark.parametrize("k,draft_kind,kv_dtype", [
    (1, "random", "bf16"), (8, "random", "bf16"), (4, "self", "bf16"),
    (2, "random", "int8"), (4, "self", "int8"),
])
def test_spec_stream_bit_identical(k, draft_kind, kv_dtype, ref_impl):
    cfg, model, params, draft, dparams = _spec_setup(draft_kind)
    _run_spec_vs_sequential(model, params, draft, dparams, k, kv_dtype)


def test_spec_stream_bit_identical_mid_acceptance(ref_impl):
    """Fitted draft/target pair with the disagreement knob: the draft
    trains on a corpus deviated at every value ≡ 0 (mod 2), so it agrees
    with the clean-fitted target on only part of the greedy steps —
    exercising partial-acceptance blocks (neither all-accept nor
    all-reject), which must still be bit-identical."""
    from repro.models.synthetic import fit_affine_lm

    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = fit_affine_lm(model, steps=300)
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    draft = make_model(dcfg, remat=False)
    dparams = fit_affine_lm(draft, steps=300, disagree_every=2)
    _run_spec_vs_sequential(model, params, draft, dparams, 4, "bf16",
                            budgets=(12, 9))


# ---------------------------------------------------------------------------
# dual-arena page accounting
# ---------------------------------------------------------------------------


def test_kv_manager_draft_arena_alloc_release_drain():
    kv = KVManager(num_pages=16, page_size=4, max_batch=2, max_pages=8,
                   draft_num_pages=8)
    g = kv.admit(np.arange(6, dtype=np.int32), rem_budget=6,
                 max_hit_suffix=8, spec_margin=4)
    assert g is not None
    # 6 prompt + 6 budget + 4 spec margin = 16 positions = 4 pages per arena
    assert len(g.pages) == 4 and len(g.draft_pages) == 4
    assert g.draft_pt_row[:4].tolist() == g.draft_pages
    # draft pages are always freshly owned: the whole span resets
    assert g.draft_reset[:4].tolist() == g.draft_pages
    kv.commit(0, g)
    assert kv.draft_pool.pages_in_use == 4
    # release covers retirement, preemption, and rejection rollback alike —
    # draft pages are never shared, so all three reduce to a lane decref
    kv.release(0)
    assert kv.draft_pool.pages_in_use == 0
    kv.assert_drained()


def test_kv_manager_draft_starvation_rolls_back_admission():
    """When the draft pool can't cover an admission, the whole admission
    declines as a unit: target-side hit refs taken by the radix lookup are
    dropped and nothing leaks."""
    kv = KVManager(num_pages=64, page_size=4, max_batch=4, max_pages=16,
                   draft_num_pages=6)
    prompt = np.arange(8, dtype=np.int32)
    g = kv.admit(prompt, rem_budget=8, max_hit_suffix=16, spec_margin=0)
    assert g is not None and len(g.draft_pages) == 4
    kv.commit(0, g)
    kv.register_prefix(prompt, g.pages)
    in_use_before = kv.pool.pages_in_use
    # second admission hits the radix prefix but needs 4 draft pages with
    # only 2 free -> must decline and roll the hit incref back
    g2 = kv.admit(prompt, rem_budget=8, max_hit_suffix=16, spec_margin=0)
    assert g2 is None
    assert kv.pool.pages_in_use == in_use_before
    assert kv.draft_pool.pages_in_use == 4
    kv.release(0)
    kv.assert_drained()


def test_spec_pool_split_partitions_budget():
    cfg = get_config("smollm-135m").reduced()
    dcfg = dataclasses.replace(cfg, name=cfg.name + "-draft", n_layers=1)
    ps = 16
    budget = 64 * kv_page_bytes(cfg, ps, "bf16")
    n = spec_pool_split(cfg, dcfg, ps, "bf16", budget)
    # per-arena page count: both arenas hold n pages within the budget...
    assert n * (kv_page_bytes(cfg, ps, "bf16")
                + kv_page_bytes(dcfg, ps, "bf16")) <= budget
    # ...and one more page per arena would overflow it
    assert (n + 1) * (kv_page_bytes(cfg, ps, "bf16")
                      + kv_page_bytes(dcfg, ps, "bf16")) > budget
    # the 1-layer draft is cheaper per page, so the split beats halving
    assert n > 64 // 4
