"""End-to-end behaviour tests for the paper's system.

Covers: training converges on the synthetic Markov corpus; packed
(no-padding) training works; failure-injected training recovers and matches
the uninterrupted run's step count; roofline accounting on a known program;
a miniature dry-run (lower+compile on 8 simulated devices with the Cluster
Builder plan) succeeds.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp


def test_training_reduces_loss(tmp_path):
    from repro.launch import train as T

    out = T.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "70",
        "--batch", "8", "--seq", "32", "--lr", "5e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "35",
    ])
    losses = out["losses"]
    assert len(losses) == 70
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_training_with_packing(tmp_path):
    from repro.launch import train as T

    out = T.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "25",
        "--batch", "8", "--seq", "32", "--lr", "5e-3", "--pack",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "25",
    ])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_training_recovers_from_failure(tmp_path):
    from repro.launch import train as T

    out = T.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--inject-failure-at", "15",
    ])
    assert out["report"].restarts == 1
    assert out["report"].completed_steps == 30
    assert out["report"].recovered_from == [10]


def test_roofline_jaxpr_counts_known_program():
    from repro.roofline.jaxpr_cost import count_costs

    def f(a, b):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, a, b)
        return out.sum()

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    costs = count_costs(f, a, b)
    # 10 iterations x 2*128^3 flops
    assert costs["flops"] == 10 * 2 * 128 ** 3
    assert costs["bytes"] > 10 * 128 * 128 * 4  # at least the weight reads


def test_roofline_hlo_collective_parse():
    from repro.roofline.hlo import collective_bytes

    hlo = """
HloModule test

%body (p: (s32[], f32[64,8])) -> (s32[], f32[64,8]) {
  %ar = f32[64,8]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = tuple(...)
}

ENTRY %main (a: f32[64,8]) -> f32[64,8] {
  %w = (s32[], f32[64,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[128,8]{1,0} all-gather(%a), dimensions={0}
  ROOT %r = f32[64,8] get-tuple-element(%w), index=1
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 5 * 64 * 8 * 4  # loop-weighted
    assert out["all-gather"] == 128 * 8 * 4
    assert out["total"] == out["all-reduce"] + out["all-gather"]


def test_mini_dryrun_8dev():
    """Cluster-Builder plan lower+compile on a small mesh in a subprocess
    (the real 256/512-chip dry-run runs via repro.launch.dryrun)."""
    script = """
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.core.cluster_builder import build_plan
    from repro.launch.mesh import make_mesh
    from repro.launch.train import make_train_step, opt_state_specs
    from repro.models.shard_hints import hints
    from repro.models.transformer import init_params, make_model
    from repro.optim.optimizer import cosine_schedule, make_optimizer

    cfg = get_config("smollm-135m").reduced()
    mesh = make_mesh((2, 4), ("data", "model"))
    model = make_model(cfg)
    ps = jax.eval_shape(lambda k: init_params(cfg, k),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    plan = build_plan(cfg, mesh, ps, batch=8)
    oi, ou = make_optimizer("adamw", cosine_schedule(1e-3, 2, 10))
    os_shape = jax.eval_shape(oi, ps)
    import jax.sharding as jsh
    psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), plan.param_specs)
    osh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                       opt_state_specs(os_shape, plan.param_specs))
    ins = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
           "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    dsh = {k: NamedSharding(mesh, plan.data_spec(2, 8)) for k in ins}
    step = make_train_step(model, ou)
    with mesh, hints(mesh, dp_axes=("data",), tp_axis="model"):
        c = jax.jit(step, in_shardings=(psh, osh, dsh),
                    donate_argnums=(0, 1)).lower(ps, os_shape, ins).compile()
    assert c.memory_analysis().temp_size_in_bytes > 0
    ca = c.cost_analysis()
    print("MINI-DRYRUN-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "MINI-DRYRUN-OK" in out.stdout
