# NOTE: deliberately does NOT set --xla_force_host_platform_device_count:
# smoke tests and benches must see 1 device (dry-run sets its own flags in
# its own process).  Multi-device tests spawn subprocesses with the flag.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
