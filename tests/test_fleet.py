"""Fleet router: prefix-affinity dispatch over engine replicas.

Correctness bar (docs/fleet.md): the router only chooses *where* a
request runs — a 1-replica fleet's token streams are bit-identical to
the plain engine's; affinity routing concentrates each shared prefix on
one replica (more tree hits than the round-robin control); shedding and
stale-affinity fallback degrade politely (reason strings and cold
prefills, never errors).
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models.transformer import init_params, make_model
from repro.serving.engine import ContinuousBatchingEngine, Request
from repro.serving.replica import Replica, replica_device_groups
from repro.serving.router import (
    AffinityIndex, FleetConfig, FleetRouter, build_fleet,
)
from repro.serving.stream import (
    clone_requests, multi_prefix_requests, shared_prefix_requests,
)

ENGINE_KW = dict(max_batch=2, buckets=(16, 32, 64), num_pages=64)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    model = make_model(cfg, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, model, params


# -- config + index units -----------------------------------------------------

def test_fleet_config_validates_route():
    with pytest.raises(ValueError, match="route"):
        FleetConfig(route="random")
    assert FleetConfig(shed_depth=3, shed_budget=1.5).shed_limit == 5
    assert FleetConfig(shed_depth=0).shed_limit == 0  # shedding disabled


def test_affinity_index_caps_match_at_len_minus_one():
    """Mirrors the radix tree's always-re-ingest-the-last-token rule: an
    exactly-block-aligned prompt matches one block short."""
    idx = AffinityIndex(block=4)
    toks = np.arange(12, dtype=np.int32)
    idx.insert(toks, replica=1)
    rep, hit = idx.lookup(toks)
    assert (rep, hit) == (1, 8)  # (12-1)//4 = 2 blocks, not 3
    rep, hit = idx.lookup(np.arange(13, dtype=np.int32))
    assert (rep, hit) == (1, 12)
    assert idx.lookup(np.arange(3, dtype=np.int32)) == (-1, 0)


def test_affinity_index_last_writer_wins():
    idx = AffinityIndex(block=4)
    toks = np.arange(8, dtype=np.int32)
    idx.insert(toks, replica=0)
    idx.insert(toks, replica=2)
    assert idx.lookup(np.arange(9, dtype=np.int32)) == (2, 8)


def test_replica_device_groups_partition_and_overflow():
    n = len(jax.devices())
    groups = replica_device_groups(n, 1)
    assert [d for g in groups for d in g] == jax.devices()
    with pytest.raises(ValueError, match="devices"):
        replica_device_groups(n + 1, 1)


# -- routing policies (no engine runs needed) ---------------------------------

def _req(rid, prompt_len, budget=4, rng=None):
    rng = rng or np.random.default_rng(rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, 100, prompt_len).astype(np.int32),
                   max_new_tokens=budget)


def test_least_loaded_uses_projected_occupancy(setup):
    """Occupancy is token-steps (bucketed prompt + remaining budget), not
    request count: one long prompt outweighs several short ones."""
    _, model, params = setup
    fleet = build_fleet(model, params, 2,
                        config=FleetConfig(route="least-loaded"), **ENGINE_KW)
    fleet.submit(_req(0, prompt_len=60, budget=4))   # replica 0: 64+4 steps
    d1 = fleet.submit(_req(1, prompt_len=8, budget=4))
    assert d1.replica == 1
    d2 = fleet.submit(_req(2, prompt_len=8, budget=4))  # 1 holds 16+4 < 68
    assert d2.replica == 1
    assert fleet.replicas[0].projected_occupancy() == 68
    assert fleet.replicas[1].projected_occupancy() == 2 * (16 + 4)


def test_rebalance_overrides_overloaded_affinity_target(setup):
    """Deadline-aware balancing: an affinity hit is abandoned when the
    target's backlog exceeds least-loaded by > rebalance_margin."""
    _, model, params = setup
    fleet = build_fleet(model, params, 2,
                        config=FleetConfig(route="affinity",
                                           rebalance_margin=50), **ENGINE_KW)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, 100, 30).astype(np.int32)

    def with_prefix(rid, tail):
        return Request(rid=rid, prompt=np.concatenate(
            [prefix, rng.integers(0, 100, tail).astype(np.int32)]),
            max_new_tokens=4)

    assert fleet.submit(with_prefix(0, 2)).kind == "least-loaded"  # cold
    hot = fleet.decisions[0].replica
    d = fleet.submit(with_prefix(1, 3))
    assert d.kind == "affinity" and d.replica == hot
    assert d.expected_hit_tokens == 16  # (30-1)//16 blocks of the prefix
    # pile 2x(64+4) token-steps more onto the hot replica -> lag > margin
    fleet.replicas[hot].submit(_req(90, prompt_len=60))
    fleet.replicas[hot].submit(_req(91, prompt_len=60))
    d = fleet.submit(with_prefix(2, 4))
    assert d.kind == "rebalanced" and d.replica != hot
    assert d.expected_hit_tokens == 0  # the hit was given up, not claimed


def test_shed_only_when_every_replica_saturated(setup):
    _, model, params = setup
    fleet = build_fleet(model, params, 2,
                        config=FleetConfig(route="least-loaded",
                                           shed_depth=2), **ENGINE_KW)
    for rid in range(3):  # queues 2/1 -> replica 1 below limit, no shed
        assert fleet.submit(_req(rid, 8)).kind == "least-loaded"
    d = fleet.submit(_req(3, 8))  # queues 2/2 after: still routed (2/1 now)
    assert d.kind == "least-loaded"
    d = fleet.submit(_req(4, 8))  # every queue at limit 2 -> shed
    assert d.kind == "shed" and d.replica is None
    assert "saturated" in d.reason and "2 replicas" in d.reason
    assert fleet.shed[0][0].rid == 4
    done = fleet.run()  # shed requests never reach an engine
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert fleet.stats()["shed"] == 1


# -- end-to-end: streams, hits, staleness -------------------------------------

def test_single_replica_fleet_matches_plain_engine(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    stream = shared_prefix_requests(rng, 6, cfg.vocab_size, prefix_len=24,
                                    suffix_range=(2, 6), budgets=(3, 7))
    plain = ContinuousBatchingEngine(model, params, **ENGINE_KW)
    for r in clone_requests(stream):
        plain.submit(r)
    want = {r.rid: r.tokens_out for r in plain.run()}

    fleet = build_fleet(model, params, 1, **ENGINE_KW)
    for r in clone_requests(stream):
        assert fleet.submit(r).replica == 0
    got = {r.rid: r.tokens_out for r in fleet.run()}
    assert got == want  # bit-identical: the router is placement-only


def test_affinity_concentrates_prefixes_and_beats_round_robin(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    stream = multi_prefix_requests(rng, 12, cfg.vocab_size, n_prefixes=2,
                                   prefix_len=24, suffix_range=(2, 6),
                                   budgets=(3, 7))
    hits = {}
    for route in ("affinity", "round-robin"):
        # high margin: this test isolates pure placement (the rebalance
        # override has its own test above)
        fleet = build_fleet(model, params, 2,
                            config=FleetConfig(route=route,
                                               rebalance_margin=10_000),
                            **ENGINE_KW)
        for r in clone_requests(stream):
            fleet.submit(r)
        done = fleet.run()
        assert len(done) == 12
        hits[route] = fleet.stats()["prefix_hits"]
    # affinity: one cold per prefix; round-robin: up to one cold per
    # (replica, prefix) pair on the same stream
    assert hits["affinity"] == 10
    assert hits["affinity"] > hits["round-robin"]


def test_stale_affinity_entry_falls_back_to_cold_prefill(setup):
    """The index records where a prefix was *sent*, not whether the
    replica still caches it: evict the tree behind the router's back and
    the re-routed request pays one cold prefill — same tokens, no error."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    stream = shared_prefix_requests(rng, 4, cfg.vocab_size, prefix_len=24,
                                    suffix_range=(2, 6), budgets=4)
    fleet = build_fleet(model, params, 2, **ENGINE_KW)
    for r in clone_requests(stream):
        fleet.submit(r)
    first = {r.rid: r.tokens_out for r in fleet.run()}
    target = next(d.replica for d in fleet.decisions)
    hits0 = fleet.stats()["prefix_hits"]

    # drop every cached page on the target replica; the index still
    # points at it
    evicted = fleet.replicas[target].engine.kv.evict_cached(10 ** 6)
    assert evicted > 0
    again = clone_requests(stream)[:1]
    d = fleet.submit(again[0])
    assert d.kind == "affinity" and d.replica == target
    assert d.expected_hit_tokens > 0  # the index's (stale) promise
    done = fleet.run()
    assert done[0].tokens_out == first[done[0].rid]  # stream unchanged
    assert fleet.stats()["prefix_hits"] == hits0  # cold prefill, no hit


def test_replica_stats_deltas_and_router_aggregation(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    stream = shared_prefix_requests(rng, 4, cfg.vocab_size, prefix_len=24,
                                    suffix_range=(2, 6), budgets=4)
    fleet = build_fleet(model, params, 2, **ENGINE_KW)
    for r in clone_requests(stream):
        fleet.submit(r)
    fleet.run()
    st = fleet.stats()
    assert st["submitted"] == 4 and st["shed"] == 0
    assert sum(p["routed"] for p in st["replicas"]) == 4
    assert sum(p["admitted"] for p in st["replicas"]) == 4
    assert st["by_kind"].get("affinity", 0) + \
        st["by_kind"].get("least-loaded", 0) == 4
    rep = st["replicas"][fleet.decisions[0].replica]
    assert rep["prefix_hit_rate"] == pytest.approx(
        rep["prefix_hits"] / rep["admitted"])
    # a fresh Replica wrapper sees only post-join deltas
    wrapped = Replica(9, fleet.replicas[0].engine)
    assert wrapped.stats()["admitted"] == 0


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="plan-placed fleet needs >= 2 devices")
def test_fleet_on_disjoint_device_groups(setup):
    """Each replica's engine lives on its own device group and the fleet
    still matches the plain single-device engine bit-for-bit."""
    from repro.core.cluster_builder import build_plan
    from repro.serving.replica import make_group_mesh

    cfg, model, params = setup
    groups = replica_device_groups(2, 1)
    plans = [build_plan(cfg, make_group_mesh(g, (1, 1), ("data", "model")),
                        mode="serve") for g in groups]
    rng = np.random.default_rng(4)
    stream = shared_prefix_requests(rng, 4, cfg.vocab_size, prefix_len=24,
                                    suffix_range=(2, 6), budgets=4)
    plain = ContinuousBatchingEngine(model, params, **ENGINE_KW)
    for r in clone_requests(stream):
        plain.submit(r)
    want = {r.rid: r.tokens_out for r in plain.run()}
    fleet = build_fleet(model, params, 2, plans=plans, **ENGINE_KW)
    for r in clone_requests(stream):
        fleet.submit(r)
    got = {r.rid: r.tokens_out for r in fleet.run()}
    assert got == want
