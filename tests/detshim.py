"""Deterministic stand-in for hypothesis when it is not installed.

The property-test files guard their import:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from detshim import given, settings
        import detshim as st

Each strategy becomes a seeded draw function and ``@given`` replays a fixed
number of deterministic examples, so the same bound checks run (with less
search power) instead of the whole module failing at collection.  Seeds are
derived with crc32 (stable across processes, unlike ``hash``).
"""
from __future__ import annotations

import zlib

import numpy as np

N_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # rng -> value


def floats(lo: float, hi: float, allow_nan: bool = False) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)))


def integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elem.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def given(*strats: _Strategy):
    def deco(fn):
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # wrapped function's parameters (it would hunt for fixtures)
        def run():
            for case in range(N_EXAMPLES):
                seed = (zlib.crc32(fn.__name__.encode()) + case) % 2 ** 32
                rng = np.random.default_rng(seed)
                fn(*[s.draw(rng) for s in strats])

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run

    return deco


def settings(**_kw):
    return lambda fn: fn
