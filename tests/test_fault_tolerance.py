"""Fault tolerance: injected failure -> recovery; elastic meshing; stragglers."""
import numpy as np
import pytest

from repro.runtime import (
    FailureInjector, SimulatedFailure, StragglerMonitor, run_with_recovery,
)
from repro.runtime.elastic import (
    accumulation_steps, elastic_mesh_shape, rebalanced_batch,
)


def test_recovery_resumes_from_checkpoint():
    saved = {}
    injector = FailureInjector({7: "node_loss", 13: "preemption"})
    log = []

    def make_state():
        return {"x": 0}

    def train_steps(state, start, stop):
        x = state["x"]
        for step in range(start, stop):
            injector.check(step)
            x += 1
            log.append(step)
        return {"x": x}

    def save(step, state):
        saved[step] = dict(state)

    def restore():
        if not saved:
            return None
        s = max(saved)
        return s, dict(saved[s])

    state, report = run_with_recovery(
        make_state, train_steps, save, restore,
        total_steps=20, checkpoint_every=5)
    assert state["x"] == 20  # every step counted exactly once post-recovery
    assert report.restarts == 2
    assert report.failed_steps == [7, 13]
    assert report.recovered_from == [5, 10]
    # steps 5,6 replayed after the failure at 7 (deterministic replay)
    assert log.count(5) == 2 and log.count(6) == 2


def test_recovery_gives_up_after_max_restarts():
    injector = FailureInjector({i: "flaky" for i in range(100)})
    injector.fired = set()  # refire every time

    class AlwaysFail(FailureInjector):
        def check(self, step):
            raise SimulatedFailure(step)

    with pytest.raises(SimulatedFailure):
        run_with_recovery(
            lambda: {}, lambda s, a, b: AlwaysFail().check(a),
            lambda s, st: None, lambda: None,
            total_steps=10, checkpoint_every=2, max_restarts=3)


def test_elastic_mesh_shapes():
    # full 2-pod fleet
    assert elastic_mesh_shape(512, 16, pod_size=256) == (
        (2, 16, 16), ("pod", "data", "model"))
    # lost one pod: single-pod mesh
    assert elastic_mesh_shape(256, 16, pod_size=256) == (
        (16, 16), ("data", "model"))
    # lost half a pod: data axis shrinks
    assert elastic_mesh_shape(128, 16) == ((8, 16), ("data", "model"))
    with pytest.raises(ValueError):
        elastic_mesh_shape(100, 16)


def test_rebalance_and_accumulation():
    assert rebalanced_batch(256, 16) == 16
    assert rebalanced_batch(256, 8) == 32
    assert accumulation_steps(256, 8, max_per_device=8) == 4


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for step in range(10):
        flagged = mon.observe(step, 0.1)
        assert not flagged
    assert mon.observe(10, 0.5)  # 5x EMA
    assert len(mon.events) == 1
    # straggler did not poison the EMA
    assert abs(mon.ema - 0.1) < 1e-6
    assert not mon.observe(11, 0.11)
